"""AllocateBits under the hood: how layer sensitivity (eq. 23) shapes the
per-layer bit widths as the budget shrinks, and what the GCD trick saves.

  PYTHONPATH=src python examples/bit_allocation_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import allocate, calibrate as cal
from repro.configs import registry
from repro.launch.train import train
from repro.models import transformer as tf


def main():
    cfg, params, _ = train(arch="llama2-7b", tiny=True, steps=100, batch=16,
                           seq=128, lr=2e-3, log_every=1000)
    stats = cal.calibrate(
        lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
        params, [{"tokens": jnp.asarray(cal.zero_shot_tokens(cfg.vocab, 128))}])
    names = [n for n in stats if n != "lm_head"]
    alphas = [stats[n].alpha for n in names]
    ms = [stats[n].m for n in names]
    print(f"{len(names)} layers; alpha range "
          f"[{min(alphas):.2f}, {max(alphas):.2f}]")
    for avg in (6.0, 4.0, 2.5):
        res = allocate.allocate_for_avg_bits(alphas, ms, avg,
                                             list(range(1, 9)))
        print(f"\nbudget {avg} bits/param  (DP slots {res.n_slots}, "
              f"gcd {res.gcd}):")
        by_layer = {}
        for n, b in zip(names, res.bits):
            layer = n.split(".")[0]
            by_layer.setdefault(layer, []).append(b)
        for layer, bits in by_layer.items():
            print(f"  {layer}: {bits}")
    # sensitivity vs depth
    print("\nalpha by layer (sensitivity decays with depth -> early layers "
          "get more bits):")
    for layer in sorted(set(n.split('.')[0] for n in names),
                        key=lambda s: int(s[1:])):
        a = np.mean([stats[n].alpha for n in names
                     if n.startswith(layer + ".")])
        print(f"  {layer}: mean alpha {a:10.2f}")


if __name__ == "__main__":
    main()
