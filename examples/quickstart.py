"""Quickstart: quantize a model with RaanA in ~20 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import calibrate as cal
from repro.core import pipeline as pipe
from repro.models import transformer as tf

# 1. a model (tiny llama-family config; swap for any of the 10 assigned archs)
cfg = registry.get_tiny("llama2-7b")
params = tf.init_params(cfg, jax.random.PRNGKey(0))

# 2. zero-shot calibration: ONE synthetic sentence, one backward pass
calib = [{"tokens": jnp.asarray(cal.zero_shot_tokens(cfg.vocab, 128))}]
stats = cal.calibrate(
    lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
    params, calib)

# 3. AllocateBits + RaBitQ-H at an arbitrary fractional budget
qparams, report = pipe.quantize_model(cfg, params, stats, avg_bits=3.3,
                                      key=jax.random.PRNGKey(1))
print(f"quantized {report.n_layers} layers -> {report.avg_bits:.3f} avg bits "
      f"in {report.wall_time_s:.1f}s")
print("bit allocation:", sorted(set(report.per_layer_bits.values())))

# 4. the quantized tree is a drop-in replacement
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 65), 0,
                                      cfg.vocab)}
print("fp loss  :", float(tf.loss_fn(cfg, params, batch)))
print("q3.3 loss:", float(tf.loss_fn(cfg, qparams, batch, scan=False)))
