"""Serve a small model with batched requests, fp vs RaanA-quantized — the
paper's deployment artifact (weight-only PTQ for cheaper inference).

  PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibrate as cal
from repro.core import pipeline as pipe
from repro.data import ByteTokenizer
from repro.launch.serve import BatchedServer
from repro.launch.train import train
from repro.models import transformer as tf


def main():
    cfg, params, _ = train(arch="llama2-7b", tiny=True, steps=150, batch=16,
                           seq=128, lr=2e-3, log_every=1000)
    tok = ByteTokenizer(cfg.vocab)
    prompts = np.stack([tok.encode("the fox watched the morning fog ")[:24]
                        for _ in range(4)])

    def serve(p, label):
        server = BatchedServer(cfg, p, max_context=64)
        server.generate(prompts, 2)  # warmup
        t0 = time.time()
        out = server.generate(prompts, 24)
        dt = time.time() - t0
        wbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(p)
                     if hasattr(x, "dtype"))
        print(f"{label:12s} {4*24/dt:6.1f} tok/s  weights={wbytes/1e6:.1f}MB  "
              f"sample: {tok.decode(out[0])!r}")
        return out

    serve(params, "fp32")
    stats = cal.calibrate(
        lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
        params, [{"tokens": jnp.asarray(cal.zero_shot_tokens(cfg.vocab, 128))}])
    for bits in (4.3, 2.3):
        qp, rep = pipe.quantize_model(cfg, params, stats, bits,
                                      jax.random.PRNGKey(0))
        serve(qp, f"raana {rep.avg_bits:.2f}b")


if __name__ == "__main__":
    main()
