"""Serve a small model with the continuous-batching paged engine, fp vs
RaanA-quantized — the paper's deployment artifact (weight-only PTQ for
cheaper inference) behind a production-shaped serving layer.

Requests with mixed prompt/generation lengths stream through a paged
KV-cache pool: admission against free blocks, chunked prefill interleaved
with decode, immediate slot reuse on completion.  The lockstep baseline
(whole batch decodes until the longest request finishes) runs the same
workload for comparison.

Every request opens with the same system prompt, so the engine's
content-addressed prefix cache (DESIGN.md §8) serves the shared blocks from
the pool after the first prefill — the printed hit rate is the fraction of
prompt tokens whose prefill was skipped entirely.

The final section demos self-speculative decoding (DESIGN.md §9): the same
weights are quantized twice from one calibration pass — a ~4.3-bit target
and a ~2.3-bit draft sharing the Hadamard rotation — and the draft proposes
tokens the target verifies in one batched step.  Greedy outputs are
token-identical to the target-only engine; the printed acceptance rate is
the fraction of draft proposals that survived verification.

  PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibrate as cal
from repro.core import pipeline as pipe
from repro.data import ByteTokenizer
from repro.launch.serve import BatchedServer
from repro.launch.train import train
from repro.models import transformer as tf
from repro.serve import PagedServer, PoolConfig, Request


def main():
    cfg, params, _ = train(arch="llama2-7b", tiny=True, steps=150, batch=16,
                           seq=128, lr=2e-3, log_every=1000)
    tok = ByteTokenizer(cfg.vocab)
    system = "you are a helpful storyteller. "     # shared by every request
    texts = ["the fox watched the morning fog ",
             "a river ran through the quiet valley and ",
             "under the old bridge the water ",
             "the morning train left without "]
    gens = [24, 8, 16, 12]
    reqs = [Request(rid=i,
                    prompt=np.asarray(tok.encode(system + t)[:48], np.int32),
                    max_new=g) for i, (t, g) in enumerate(zip(texts, gens))]

    def serve(p, label):
        pool = PoolConfig(max_slots=2, block_size=8, max_context=96,
                          prefill_chunk=8)
        engine = PagedServer(cfg, p, pool)
        engine.run([Request(rid=-1, prompt=np.full(8, cfg.vocab - 1,
                                                   np.int32), max_new=2)])
        engine.stats.clear()                        # warmup/compile
        t0 = time.time()
        results = engine.run(list(reqs))
        dt = time.time() - t0
        n_tok = sum(len(r.tokens) for r in results.values())
        wbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(p)
                     if hasattr(x, "dtype"))
        print(f"{label:12s} {n_tok/dt:6.1f} tok/s  weights={wbytes/1e6:.1f}MB  "
              f"occupancy={engine.stats['mean_occupancy']:.2f}  "
              f"prefix_hit_rate={engine.stats.get('prefix_hit_rate', 0):.2f} "
              f"(saved {engine.stats.get('prefill_tokens_saved', 0)} prefill "
              f"tokens)  sample: {tok.decode(results[0].tokens)!r}")
        return results

    def serve_lockstep(p, label):
        server = BatchedServer(cfg, p, max_context=96)
        prompts = np.stack([r.prompt for r in reqs])
        gen = max(r.max_new for r in reqs)          # hostage effect
        server.generate(prompts, 2)                 # warmup/compile
        t0 = time.time()
        out = server.generate(prompts, gen)
        dt = time.time() - t0
        useful = sum(r.max_new for r in reqs)
        print(f"{label:12s} {useful/dt:6.1f} tok/s (useful; batch decodes "
              f"{len(reqs)}x{gen} slots)  sample: {tok.decode(out[0])!r}")

    serve(params, "fp32 paged")
    serve_lockstep(params, "fp32 lock")
    stats = cal.calibrate(
        lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
        params, [{"tokens": jnp.asarray(cal.zero_shot_tokens(cfg.vocab, 128))}])
    for bits in (4.3, 2.3):
        qp, rep = pipe.quantize_model(cfg, params, stats, bits,
                                      jax.random.PRNGKey(0))
        serve(qp, f"raana {rep.avg_bits:.2f}b")

    # --- self-speculative decoding: one calibration pass, two budgets ---
    tq, trep, dq, drep = pipe.quantize_model_dual(
        cfg, params, stats, 4.3, 2.3, jax.random.PRNGKey(0))
    pool = PoolConfig(max_slots=2, block_size=8, max_context=96,
                      prefill_chunk=8)
    spec = PagedServer(cfg, tq, pool, draft_params=dq, speculate=3)
    spec.run([Request(rid=-1, prompt=np.full(8, cfg.vocab - 1, np.int32),
                      max_new=4)])
    spec.stats.clear()                              # warmup/compile
    t0 = time.time()
    results = spec.run([Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                        for r in reqs])
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in results.values())
    print(f"{'speculative':12s} {n_tok/dt:6.1f} tok/s  "
          f"target={trep.avg_bits:.2f}b draft={drep.avg_bits:.2f}b "
          f"k={spec.speculate}  "
          f"acceptance_rate={spec.stats['acceptance_rate']:.2f} "
          f"({spec.stats['spec_accepted']}/{spec.stats['spec_proposed']} "
          f"drafts accepted over {spec.stats['spec_rounds']} rounds)  "
          f"sample: {tok.decode(results[0].tokens)!r}")


if __name__ == "__main__":
    main()
