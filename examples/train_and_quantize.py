"""End-to-end driver: train a small LM for a few hundred steps on the
synthetic corpus (with fault-tolerant checkpointing), then post-training
quantize it with RaanA and compare perplexities across bit budgets.

  PYTHONPATH=src python examples/train_and_quantize.py [--steps 300]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibrate as cal
from repro.core import pipeline as pipe
from repro.data import LMBatchLoader, make_corpus_tokens
from repro.launch.train import train
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="llama2-7b")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        cfg, params, losses = train(arch=args.arch, tiny=True,
                                    steps=args.steps, batch=16, seq=128,
                                    lr=2e-3, ckpt_dir=ckpt_dir,
                                    ckpt_every=100, log_every=50)
    print(f"\ntrained {cfg.name}: loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    corpus = make_corpus_tokens(cfg.vocab, 30000)
    loader = LMBatchLoader(corpus, 8, 128)
    eval_batches = [{"tokens": jnp.asarray(b)} for b in loader.eval_batches(4)]

    def ppl(p):
        return float(np.exp(np.mean([
            float(tf.loss_fn(cfg, p, b, scan=False)) for b in eval_batches])))

    print(f"fp32 ppl: {ppl(params):.3f}")
    stats = cal.calibrate(
        lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
        params, [{"tokens": jnp.asarray(loader.next_batch()[:1])}
                 for _ in range(5)])
    for avg_bits in (4.3, 3.3, 2.3):
        qp, rep = pipe.quantize_model(cfg, params, stats, avg_bits,
                                      jax.random.PRNGKey(1))
        hist = {}
        for b in rep.per_layer_bits.values():
            hist[b] = hist.get(b, 0) + 1
        print(f"RaanA {avg_bits:.1f} bits (achieved {rep.avg_bits:.2f}): "
              f"ppl {ppl(qp):.3f}  allocation {dict(sorted(hist.items()))}")


if __name__ == "__main__":
    main()
