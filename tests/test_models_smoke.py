"""Per-assigned-architecture smoke tests (deliverable f): reduced config,
one forward + one train step on CPU, asserting shapes + no NaNs, and
scan == unrolled equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as tf
from repro.optim import adamw_init
from repro.runtime.steps import make_train_step

ARCHS = list(registry.ARCH_IDS)


def _batch(cfg, b=2, s=16, key=None):
    key = key or jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (b, s + 1), 0, cfg.vocab)}
    if cfg.pos == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s + 1, dtype=jnp.int32)[None, None], (3, b, s + 1))
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            key, (b, cfg.n_audio_ctx, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = registry.get_tiny(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg)
    logits, aux = tf.forward(cfg, params, batch["tokens"][:, :-1],
                             positions=(batch.get("positions")[..., :-1]
                                        if "positions" in batch else None),
                             enc_embeds=batch.get("enc_embeds"), scan=True)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_scan_equals_unrolled(arch):
    cfg = registry.get_tiny(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(2))
    batch = _batch(cfg)
    l1 = tf.loss_fn(cfg, params, batch, scan=True)
    l2 = tf.loss_fn(cfg, params, batch, scan=False)
    np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = registry.get_tiny(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(3))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, microbatches=1, peak_lr=1e-3,
                                   warmup=1, total_steps=10))
    params, opt, metrics = step(params, opt, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.isfinite(leaf).all())


def test_microbatched_grads_match_full():
    cfg = registry.get_tiny("internlm2-1.8b")
    params = tf.init_params(cfg, jax.random.PRNGKey(4))
    opt = adamw_init(params)
    batch = _batch(cfg, b=4, s=16)
    s1 = make_train_step(cfg, microbatches=1, peak_lr=0.0, warmup=1,
                         total_steps=10)
    s2 = make_train_step(cfg, microbatches=2, peak_lr=0.0, warmup=1,
                         total_steps=10)
    _, _, m1 = s1(params, opt, batch)
    _, _, m2 = s2(params, opt, batch)
    np.testing.assert_allclose(m1["loss"], m2["loss"], rtol=1e-4)
    np.testing.assert_allclose(m1["grad_norm"], m2["grad_norm"], rtol=1e-3)
