"""Chunked/parallel recurrences vs naive per-step references (RWKV6 WKV,
RG-LRU associative scan)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import rglru as rgl
from repro.models import rwkv6 as rw
from repro.models.transformer import _init_rglru, _init_rwkv_tm
from repro.configs import registry


def test_wkv6_chunked_matches_recurrent():
    cfg = registry.get_tiny("rwkv6-3b")
    p = _init_rwkv_tm(cfg, jax.random.PRNGKey(0), jnp.float32)
    b, s, d = 2, 37, cfg.d_model           # s deliberately not chunk-aligned
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    y_par = rw.time_mix(p, x, n_heads=cfg.n_heads, head_dim=cfg.hd)
    # naive recurrence via the decode step
    st = rw.RWKVState.init(b, cfg.n_heads, cfg.hd, d)
    outs = []
    for t in range(s):
        o, st = rw.time_mix_decode(p, x[:, t], st, n_heads=cfg.n_heads,
                                   head_dim=cfg.hd)
        outs.append(o)
    y_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(y_par, y_seq, rtol=2e-3, atol=2e-3)


def test_wkv6_state_handoff():
    cfg = registry.get_tiny("rwkv6-3b")
    p = _init_rwkv_tm(cfg, jax.random.PRNGKey(2), jnp.float32)
    b, s, d = 1, 24, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(3), (b, s, d)) * 0.5
    _, s_final = rw.time_mix(p, x, n_heads=cfg.n_heads, head_dim=cfg.hd,
                             return_state=True)
    st = rw.RWKVState.init(b, cfg.n_heads, cfg.hd, d)
    for t in range(s):
        _, st = rw.time_mix_decode(p, x[:, t], st, n_heads=cfg.n_heads,
                                   head_dim=cfg.hd)
    np.testing.assert_allclose(s_final, st.s, rtol=2e-3, atol=2e-3)


def test_rglru_scan_matches_step():
    cfg = registry.get_tiny("recurrentgemma-2b")
    p = _init_rglru(cfg, jax.random.PRNGKey(4), jnp.float32)
    b, s, d = 2, 19, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(5), (b, s, d)) * 0.5
    y_par, st_final = rgl.rglru_block(p, x, return_state=True)
    st = rgl.RGLRUState.init(b, cfg.rglru_width or d)
    outs = []
    for t in range(s):
        o, st = rgl.rglru_decode(p, x[:, t], st)
        outs.append(o)
    y_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(y_par, y_seq, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_final.h, st.h, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_final.conv, st.conv, rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_naive():
    from repro.models.attention import flash_attention
    b, s, h, kv, hd = 2, 50, 4, 2, 16
    key = jax.random.PRNGKey(6)
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))
    out = flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    # naive reference
    g = h // kv
    qf = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, k) * hd ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bkgqs,bskd->bqkgd", probs, v).reshape(b, s, h, hd)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_flash_attention_window_matches_naive():
    from repro.models.attention import flash_attention
    b, s, h, hd, w = 1, 40, 2, 8, 7
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd))
    out = flash_attention(q, k, v, causal=True, window=w, q_chunk=8,
                          k_chunk=8)
    pos = jnp.arange(s)
    mask = (pos[None, :] <= pos[:, None]) & (pos[:, None] - pos[None, :] < w)
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k) * hd ** -0.5
    scores = jnp.where(mask[None, None], scores, -1e30)
    ref = jnp.einsum("bhqs,bshd->bqhd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
