"""Property tests for the FWHT / practical RHT (paper App. A.1, C.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hadamard as h

DIMS_POW2 = [2, 8, 64, 256, 1024, 4096]
DIMS_ANY = [3, 5, 48, 100, 768, 2560, 3072, 5120]


@pytest.mark.parametrize("d", DIMS_POW2)
def test_fwht_involution_and_norm(d):
    x = jax.random.normal(jax.random.PRNGKey(d), (4, d))
    y = h.fwht(x)
    np.testing.assert_allclose(h.fwht(y), x, atol=1e-4)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_fwht_matches_dense_matrix():
    d = 128
    x = jax.random.normal(jax.random.PRNGKey(0), (3, d))
    hm = h.hadamard_matrix(d)
    np.testing.assert_allclose(h.fwht(x), x @ hm, atol=1e-4)


def test_fwht_rejects_non_pow2():
    with pytest.raises(ValueError):
        h.fwht(jnp.ones((2, 48)))


@settings(deadline=None, max_examples=20)
@given(d=st.sampled_from(DIMS_ANY), seed=st.integers(0, 2**31 - 1))
def test_practical_rht_preserves_inner_products(d, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_hat = h.largest_pow2_leq(d)
    s1, s2 = h.rademacher(k1, d_hat), h.rademacher(k2, d_hat)
    a = jax.random.normal(k3, (3, d))
    b = jax.random.normal(k4, (d, 5))
    ta = h.practical_rht(a, s1, s2, axis=-1)
    tb = h.practical_rht(b, s1, s2, axis=0)
    ref = a @ b
    np.testing.assert_allclose(ta @ tb, ref,
                               atol=2e-3 * float(jnp.abs(ref).max() + 1))


@settings(deadline=None, max_examples=20)
@given(d=st.sampled_from(DIMS_ANY), seed=st.integers(0, 2**31 - 1))
def test_practical_rht_inverse(d, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    d_hat = h.largest_pow2_leq(d)
    s1, s2 = h.rademacher(k1, d_hat), h.rademacher(k2, d_hat)
    x = jax.random.normal(k3, (2, d))
    y = h.practical_rht(x, s1, s2, axis=-1)
    np.testing.assert_allclose(h.practical_rht_inverse(y, s1, s2, axis=-1),
                               x, atol=1e-4)


def test_rht_flattens_outliers():
    """The whole point of the rotation: a spiky vector becomes dense."""
    d = 1024
    x = jnp.zeros((1, d)).at[0, 3].set(100.0)
    s = h.rademacher(jax.random.PRNGKey(1), d)
    y = h.rht(x, s)
    assert float(jnp.max(jnp.abs(y))) < 5.0   # 100/sqrt(1024) ~ 3.1
