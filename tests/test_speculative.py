"""Self-speculative decoding correctness (DESIGN.md §9).

The contract, in order of importance: (1) greedy speculation is
token-identical to the non-speculative paged path no matter how bad the
draft is; (2) at temperature > 0 the acceptance rule emits tokens with
exactly the target model's distribution; (3) the draft/catch-up/verify
steps each compile once under batch churn and mixed accept/reject lengths;
(4) ``quantize_model_dual`` really shares the calibration and rotation
between target and draft.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import (GEN_LENS, PROMPT_LENS, mixed_requests, noisy,
                     small_pool, tiny)

from repro.core import calibrate as cal
from repro.core import pipeline as pipe
from repro.models import transformer as tf
from repro.serve import PagedServer, speculative_accept

pytestmark = pytest.mark.tier2  # slow end-to-end serving suite

# Parity archs per the tentpole: dense GQA and sliding-window MoE (the
# windowed ring is the hard case — speculative writes must not clobber
# still-windowed history; PoolConfig.lookahead guarantees it).
SPEC_ARCHS = ["llama2-7b", "mixtral-8x7b"]


# ------------------------------------------------------------ greedy parity


@pytest.mark.parametrize("arch", SPEC_ARCHS)
@pytest.mark.parametrize("draft_kind", ["perfect", "noisy"])
def test_spec_greedy_parity(arch, draft_kind):
    """Greedy spec-on output is token-identical to spec-off, whether the
    draft agrees with the target (all-accept + bonus path) or frequently
    diverges (rejection + replacement path)."""
    cfg = tiny(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    draft = params if draft_kind == "perfect" else noisy(params, 0.005)
    reqs = mixed_requests(cfg)
    ref = PagedServer(cfg, params, small_pool()).run(
        [dataclasses.replace(r) for r in reqs])
    spec = PagedServer(cfg, params, small_pool(), draft_params=draft,
                       speculate=3)
    got = spec.run(reqs)
    assert set(got) == {r.rid for r in reqs}
    for r in reqs:
        np.testing.assert_array_equal(
            got[r.rid].tokens, ref[r.rid].tokens,
            err_msg=f"{arch}/{draft_kind}: rid={r.rid}")
    rate = spec.stats["acceptance_rate"]
    if draft_kind == "perfect":
        assert rate == 1.0          # identical models: every draft accepted
    else:
        assert 0.0 < rate < 1.0     # mixed accept/reject actually exercised


def test_spec_eos_truncates_mid_round():
    """A request whose EOS token is emitted mid-round stops at its first
    occurrence, exactly like the non-speculative engine."""
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    reqs = mixed_requests(cfg)
    ref = PagedServer(cfg, params, small_pool()).run(
        [dataclasses.replace(r) for r in reqs])
    eos = int(ref[0].tokens[2])
    n_stop = int(np.argmax(np.asarray(ref[0].tokens) == eos)) + 1
    reqs = [dataclasses.replace(r, eos=eos if r.rid == 0 else None)
            for r in reqs]
    spec = PagedServer(cfg, params, small_pool(),
                       draft_params=noisy(params, 0.005), speculate=3)
    got = spec.run(reqs)
    assert int(got[0].tokens[-1]) == eos
    assert len(got[0].tokens) == n_stop
    np.testing.assert_array_equal(got[0].tokens, ref[0].tokens[:n_stop])
    # pool fully drained back (draft arena shares the allocator)
    assert spec.allocator.free_blocks == spec.allocator.num_blocks - 1


def test_spec_bypasses_recurrent_archs():
    """Recurrent state can't roll back rejected tokens: the engine bypasses
    speculation (documented in DESIGN.md §9) and still serves correctly."""
    cfg = tiny("rwkv6-3b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    reqs = mixed_requests(cfg, n=3)
    ref = PagedServer(cfg, params, small_pool()).run(
        [dataclasses.replace(r) for r in reqs])
    eng = PagedServer(cfg, params, small_pool(), draft_params=params,
                      speculate=3)
    assert not eng.speculating and eng.speculate == 0
    got = eng.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(got[r.rid].tokens, ref[r.rid].tokens)
    assert eng.verify_trace_count == 0          # spec path never ran


# ------------------------------------------------------- compile-once + API


def test_spec_steps_compile_once_under_churn():
    """Catch-up, draft and verify steps each trace exactly once while the
    batch churns through admissions/completions with mixed accept/reject
    lengths (the single-token decode step is never used in spec mode)."""
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    spec = PagedServer(cfg, params, small_pool(),
                       draft_params=noisy(params, 0.005), speculate=3)
    results = spec.run(mixed_requests(cfg))
    assert len(results) == len(PROMPT_LENS)
    assert spec.stats["spec_rounds"] > 1
    assert 0 < spec.stats["spec_accepted"] < spec.stats["spec_proposed"]
    assert spec.catchup_trace_count == 1, "draft catch-up step retraced"
    assert spec.draft_trace_count == 1, "draft decode step retraced"
    assert spec.verify_trace_count == 1, "target verify step retraced"
    assert spec.decode_trace_count == 0


def test_spec_requires_draft_params():
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="draft_params"):
        PagedServer(cfg, params, small_pool(), speculate=2)


def test_spec_reserves_lookahead():
    """A speculating engine pads per-request ring capacity by k so verify
    writes for later-rejected tokens can never wrap onto live history."""
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    eng = PagedServer(cfg, params, small_pool(), draft_params=params,
                      speculate=3)
    assert eng.pool.lookahead == 3
    base = PagedServer(cfg, params, small_pool())
    assert base.pool.lookahead == 0


# --------------------------------------------------- acceptance-rule units


def test_accept_rule_greedy_semantics():
    k, v = 3, 8
    rng = np.random.default_rng(0)
    tl = rng.normal(size=(k + 1, v))
    stars = np.argmax(tl, axis=1)
    # all proposals match -> k accepts + bonus argmax
    toks, n = speculative_accept(tl, tl[:k], stars[:k], 0.0, rng)
    assert n == k and toks == list(stars)
    # first mismatch at position 1 -> one accept, then the correction
    bad = stars[:k].copy()
    bad[1] = (bad[1] + 1) % v
    toks, n = speculative_accept(tl, tl[:k], bad, 0.0, rng)
    assert n == 1 and toks == [int(stars[0]), int(stars[1])]
    # immediate mismatch -> zero accepts, correction only
    bad0 = stars[:k].copy()
    bad0[0] = (bad0[0] + 1) % v
    toks, n = speculative_accept(tl, tl[:k], bad0, 0.0, rng)
    assert n == 0 and toks == [int(stars[0])]


def test_accept_rule_preserves_target_distribution():
    """Statistical pin of the rejection-sampling lemma: across many rounds
    with draft proposals drawn from the draft distribution, the empirical
    distribution of emitted tokens at each position matches target-only
    sampling (total-variation distance within Monte-Carlo noise)."""
    k, v, temp, trials = 2, 6, 0.8, 30000
    gen = np.random.default_rng(123)
    tl = gen.normal(scale=1.5, size=(k + 1, v))
    dl = gen.normal(scale=1.5, size=(k, v))

    def dist(logits):
        e = np.exp(logits / temp - (logits / temp).max())
        return e / e.sum()

    p_t = [dist(tl[i]) for i in range(k + 1)]
    p_d = [dist(dl[i]) for i in range(k)]
    rng = np.random.default_rng(7)
    counts = [np.zeros(v) for _ in range(2)]
    n_seen = [0, 0]
    for _ in range(trials):
        drafts = np.array([rng.choice(v, p=p_d[i]) for i in range(k)])
        toks, _ = speculative_accept(tl, dl, drafts, temp, rng)
        for pos in range(min(len(toks), 2)):
            counts[pos][toks[pos]] += 1
            n_seen[pos] += 1
    for pos in range(2):
        emp = counts[pos] / n_seen[pos]
        tv = 0.5 * np.abs(emp - p_t[pos]).sum()
        assert tv < 0.02, (f"position {pos}: TV {tv:.4f} vs target-only "
                           f"sampling (n={n_seen[pos]})")


def test_spec_engine_sampling_smoke():
    """Temperature > 0 end-to-end: the speculating engine completes a mixed
    workload and reports sane acceptance stats (the distribution itself is
    pinned at the acceptance-rule level above)."""
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    spec = PagedServer(cfg, params, small_pool(), temperature=0.9,
                       draft_params=noisy(params, 0.005), speculate=2)
    results = spec.run(mixed_requests(cfg, n=3))
    for rid, res in results.items():
        assert len(res.tokens) == GEN_LENS[rid]
    assert 0.0 <= spec.stats["acceptance_rate"] <= 1.0


# ------------------------------------------------------- dual quantization


def test_dual_quantization_shares_calibration_and_rotation():
    """quantize_model_dual: one stats dict, one PRNG key -> the draft's
    Rademacher sign leaves are the *same buffers* as the target's, fp
    leaves are shared by reference, and the draft's realized budget is
    genuinely lower."""
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    toks = cal.zero_shot_tokens(cfg.vocab, 32)
    stats = cal.calibrate(
        lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
        params, [{"tokens": jnp.asarray(toks)}])
    tq, tr, dq, dr = pipe.quantize_model_dual(
        cfg, params, stats, 4.0, 2.2, jax.random.PRNGKey(1),
        bit_choices=(1, 2, 3, 4, 5), n_candidates=2)
    assert dr.avg_bits < tr.avg_bits
    n_checked = 0
    for jpos in range(len(tq["layers"])):
        for idx in range(len(tq["layers"][jpos])):
            tl, dl = tq["layers"][jpos][idx], dq["layers"][jpos][idx]

            def walk(t, d):
                nonlocal n_checked
                for key in t:
                    if isinstance(t[key], dict):
                        walk(t[key], d[key])
                    elif hasattr(t[key], "signs1"):
                        assert d[key].signs1 is t[key].signs1
                        assert (d[key].signs2 is t[key].signs2
                                or d[key].signs2 is None)
                        n_checked += 1
            walk(tl, dl)
    assert n_checked > 0
    assert dq["embed"] is tq["embed"]           # fp leaves shared


def test_spec_engine_with_real_dual_quantization():
    """End-to-end: a dual-quantized (target, draft) pair serves greedily
    through the speculating engine, token-identical to the target-only
    engine."""
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    toks = cal.zero_shot_tokens(cfg.vocab, 32)
    stats = cal.calibrate(
        lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
        params, [{"tokens": jnp.asarray(toks)}])
    tq, _, dq, _ = pipe.quantize_model_dual(
        cfg, params, stats, 3.0, 1.8, jax.random.PRNGKey(1),
        bit_choices=(1, 2, 3, 4), n_candidates=2)
    reqs = mixed_requests(cfg, n=2)
    ref = PagedServer(cfg, tq, small_pool()).run(
        [dataclasses.replace(r) for r in reqs])
    spec = PagedServer(cfg, tq, small_pool(), draft_params=dq, speculate=2)
    got = spec.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(got[r.rid].tokens, ref[r.rid].tokens)
