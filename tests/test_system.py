"""End-to-end system test: train a tiny LM on the synthetic corpus, quantize
with RaanA (few-shot), and verify (a) trained ppl improved, (b) quantized
model tracks the fp model closely at moderate bits, (c) quantized serving
generates the same continuations as reconstructed-weight evaluation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import calibrate as cal
from repro.core import pipeline as pipe
from repro.data import LMBatchLoader, make_corpus_tokens
from repro.launch.train import train
from repro.models import transformer as tf

pytestmark = pytest.mark.tier2  # slow end-to-end train+quantize+serve


@pytest.fixture(scope="module")
def trained():
    cfg, params, losses = train(arch="llama2-7b", tiny=True, steps=60,
                                batch=8, seq=64, lr=2e-3, log_every=1000)
    corpus = make_corpus_tokens(cfg.vocab, 20000, seed=0)
    return cfg, params, losses, corpus


def test_training_reduces_loss(trained):
    _, _, losses, _ = trained
    assert losses[-1] < losses[0] - 0.5


def test_quantized_ppl_tracks_fp(trained):
    cfg, params, _, corpus = trained
    loader = LMBatchLoader(corpus, 8, 64)
    eval_batches = [{"tokens": jnp.asarray(b)} for b in loader.eval_batches(2)]
    calib = [{"tokens": jnp.asarray(b)} for b in loader.eval_batches(2, 2)]
    stats = cal.calibrate(
        lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
        params, calib)

    def ppl(p):
        nll = np.mean([float(tf.loss_fn(cfg, p, b, scan=False))
                       for b in eval_batches])
        return float(np.exp(nll))

    p_fp = ppl(params)
    qp6, _ = pipe.quantize_model(cfg, params, stats, 6.3,
                                 jax.random.PRNGKey(1))
    p_q6 = ppl(qp6)
    qp2, _ = pipe.quantize_model(cfg, params, stats, 2.3,
                                 jax.random.PRNGKey(1))
    p_q2 = ppl(qp2)
    # 6.3 bits ~ lossless; 2.3 bits degrades but stays in the same regime
    assert p_q6 < p_fp * 1.10, (p_fp, p_q6)
    assert p_q2 < p_fp * 3.0, (p_fp, p_q2)
    assert p_q6 <= p_q2 + 1e-6


def test_quantized_serving_matches_reconstructed(trained):
    cfg, params, _, corpus = trained
    stats = cal.calibrate(
        lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
        params, [{"tokens": jnp.asarray(
            cal.zero_shot_tokens(cfg.vocab, 64))}])
    qp, _ = pipe.quantize_model(cfg, params, stats, 4.3,
                                jax.random.PRNGKey(2))
    # reconstructed-weight model (drop-in fp evaluation of the estimator)
    from repro.core.qlinear import QuantizedLinear, reconstruct_weight
    recon = jax.tree.map(
        lambda l: reconstruct_weight(l) if isinstance(l, QuantizedLinear)
        else l, qp, is_leaf=lambda l: isinstance(l, QuantizedLinear))
    batch = {"tokens": jnp.asarray(corpus[:65][None, :])}
    l_q = float(tf.loss_fn(cfg, qp, batch, scan=False))
    l_r = float(tf.loss_fn(cfg, recon, batch, scan=False))
    np.testing.assert_allclose(l_q, l_r, rtol=1e-3)


def test_serve_quantized_generates(trained):
    cfg, params, _, _ = trained
    from repro.launch.serve import BatchedServer
    server = BatchedServer(cfg, params, max_context=48)
    prompts = np.tile(np.arange(16, dtype=np.int32)[None], (3, 1))
    out = server.generate(prompts, 8)
    assert out.shape == (3, 8)
    out2 = server.generate(prompts, 8)
    np.testing.assert_array_equal(out, out2)   # greedy => deterministic
