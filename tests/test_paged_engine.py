"""Continuous-batching engine correctness: the paged engine must produce
token-identical greedy outputs to the lockstep baseline on tiny archs with
mixed prompt/generation lengths, while its jitted decode step compiles
exactly once as the batch composition churns (admissions, completions,
queued requests joining mid-flight)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from helpers import (GEN_LENS, PROMPT_LENS, mixed_requests, small_pool,
                     tiny)

from repro.launch.serve import BatchedServer
from repro.models import transformer as tf
from repro.serve import PagedServer, PoolConfig, Request
from repro.serve.pool import BlockAllocator, request_blocks

pytestmark = pytest.mark.tier2  # slow end-to-end serving suite

# One arch per cache family: dense GQA, sliding-window MoE (ring blocks),
# MLA latent slots, RWKV recurrent slots, RG-LRU + windowed-attn hybrid.
PARITY_ARCHS = ["llama2-7b", "mixtral-8x7b", "deepseek-v2-236b", "rwkv6-3b",
                "recurrentgemma-2b"]


def _lockstep_reference(cfg, params, reqs):
    """Per-request lockstep generate (B=1) — the greedy ground truth."""
    outs = {}
    for r in reqs:
        server = BatchedServer(cfg, params,
                               max_context=len(r.prompt) + r.max_new)
        outs[r.rid] = server.generate(r.prompt[None], r.max_new)[0]
    return outs


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_paged_matches_lockstep_greedy(arch):
    cfg = tiny(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    reqs = mixed_requests(cfg)
    ref = _lockstep_reference(cfg, params, reqs)
    pool = small_pool()
    engine = PagedServer(cfg, params, pool)
    results = engine.run(reqs)
    assert set(results) == {r.rid for r in reqs}
    for r in reqs:
        got = results[r.rid].tokens
        np.testing.assert_array_equal(
            got, ref[r.rid],
            err_msg=f"{arch}: rid={r.rid} plen={len(r.prompt)} "
                    f"gen={r.max_new}")


def test_decode_step_compiles_once_under_churn():
    """Batch composition churns (2 slots, 5 mixed-length requests, queued
    joins, completions) yet the jitted paged decode step traces exactly
    once — the no-retrace property the engine's occupancy depends on."""
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    pool = small_pool(prefill_chunk=8)
    engine = PagedServer(cfg, params, pool)
    results = engine.run(mixed_requests(cfg))
    assert len(results) == len(PROMPT_LENS)
    assert engine.stats["decode_steps"] > 0
    assert engine.decode_trace_count == 1, (
        f"paged decode step retraced {engine.decode_trace_count} times")


def test_eos_frees_slot_and_blocks_immediately():
    """A request hitting EOS mid-generation completes early and returns all
    of its blocks/slot to the pool."""
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    reqs = mixed_requests(cfg)
    pool = small_pool()
    free_ref = _lockstep_reference(cfg, params, reqs)
    # pick a token request 0 actually emits as the EOS sentinel; generation
    # must truncate at its FIRST occurrence
    eos = int(free_ref[0][2])
    n_stop = int(np.argmax(np.asarray(free_ref[0]) == eos)) + 1
    assert n_stop < len(free_ref[0]) or int(free_ref[0][-1]) == eos
    reqs0 = [dataclasses.replace(r, eos=eos if r.rid == 0 else None)
             for r in reqs]
    engine = PagedServer(cfg, params, pool)
    results = engine.run(reqs0)
    assert int(results[0].tokens[-1]) == eos
    assert len(results[0].tokens) == n_stop     # truncated at EOS
    np.testing.assert_array_equal(results[0].tokens, free_ref[0][:n_stop])
    # pool fully drained back
    assert engine.allocator.free_blocks == engine.allocator.num_blocks - 1
    assert sorted(engine.free_slots) == list(range(pool.max_slots))


def test_admission_blocks_until_capacity():
    """With a pool sized for ~one request, requests serialize through
    admission control but all complete with correct outputs."""
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    reqs = mixed_requests(cfg)[:3]
    need = max(request_blocks(
        cfg, PoolConfig(block_size=4, max_context=32),
        len(r.prompt) + r.max_new) for r in reqs)
    pool = small_pool(num_blocks=need + 2)
    ref = _lockstep_reference(cfg, params, reqs)
    engine = PagedServer(cfg, params, pool)
    results = engine.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(results[r.rid].tokens, ref[r.rid])


def test_block_allocator_accounting():
    a = BlockAllocator(8)
    assert a.free_blocks == 7                   # block 0 reserved
    got = a.alloc(3)
    assert got is not None and len(set(got)) == 3 and 0 not in got
    assert a.alloc(5) is None                   # only 4 left
    a.free(got)
    assert a.free_blocks == 7


def test_kv_dtype_bf16_parity():
    """The KV arena honors PoolConfig.kv_dtype: bf16 pools hold bf16 blocks
    and paged prefill+decode logits stay within bf16 rounding of the f32
    pool (teacher-forced, so the comparison is step-for-step)."""
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    pool32 = small_pool(prefill_chunk=8)
    poolbf = dataclasses.replace(pool32, kv_dtype=jnp.bfloat16)
    from repro.models import decode as decmod
    from repro.serve.pool import init_pool_caches

    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (8,), 0,
                                           cfg.vocab), np.int32)
    need = request_blocks(cfg, pool32, 16)
    bt = np.zeros(max(request_blocks(cfg, pool32, 32), 1), np.int32)
    bt[:need] = np.arange(1, need + 1)
    ring = jnp.int32(need * pool32.block_size)

    outs = []
    for pool in (pool32, poolbf):
        caches = init_pool_caches(cfg, params, pool)
        assert caches[0]["k"].dtype == pool.kv_dtype
        logits, caches = decmod.prefill_chunk_paged(
            cfg, params, caches, jnp.asarray(prompt)[None], jnp.int32(0),
            jnp.int32(0), jnp.asarray(bt), ring)
        seq = [logits[0]]
        tok = jnp.argmax(logits[0])
        for t in range(4):                      # teacher-forced decode steps
            tokens = jnp.zeros((2, 1), jnp.int32).at[0, 0].set(tok)
            pos = jnp.zeros(2, jnp.int32).at[0].set(8 + t)
            active = jnp.zeros(2, bool).at[0].set(True)
            bts = jnp.zeros((2, len(bt)), jnp.int32).at[0].set(bt)
            rings = jnp.ones(2, jnp.int32).at[0].set(ring)
            logits, caches = decmod.decode_step_paged(
                cfg, params, caches, tokens, pos, active, bts, rings)
            seq.append(logits[0])
            tok = jnp.argmax(logits[0])         # same argmax path each pool
        outs.append(np.stack([np.asarray(x) for x in seq]))
    scale = np.abs(outs[0]).max()
    np.testing.assert_allclose(outs[1], outs[0], atol=0.02 * max(scale, 1.0),
                               rtol=0.05)


def test_kv_dtype_bf16_engine_serves():
    """End-to-end: a bf16-pool engine completes a mixed workload (greedy
    tokens may legitimately differ from f32 at bf16 precision, so this pins
    liveness + accounting, while the teacher-forced test pins numerics)."""
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    pool = small_pool(kv_dtype=jnp.bfloat16)
    engine = PagedServer(cfg, params, pool)
    assert engine.caches[0]["k"].dtype == jnp.bfloat16
    results = engine.run(mixed_requests(cfg))
    assert len(results) == len(PROMPT_LENS)
    for rid, res in results.items():
        assert len(res.tokens) == GEN_LENS[rid]
    assert engine.allocator.free_blocks == engine.allocator.num_blocks - 1


def test_submit_rejects_oversized():
    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    engine = PagedServer(cfg, params, PoolConfig(max_slots=1, block_size=4,
                                                 max_context=16))
    with pytest.raises(ValueError):
        engine.submit(Request(rid=0, prompt=np.zeros(12, np.int32),
                              max_new=8))
