"""QuantizedLinear: apply == x @ reconstruct, tricks reduce error, packing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import packing
from repro.core.qlinear import (QuantizedGrouped, quantize_grouped,
                                quantize_linear, reconstruct_weight)


@settings(deadline=None, max_examples=10)
@given(d=st.sampled_from([96, 256, 300, 768]),
       c=st.sampled_from([32, 100]),
       bits=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 1000))
def test_apply_equals_reconstruct(d, c, bits, seed):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (d, c))
    col_norms = np.abs(np.asarray(jax.random.normal(
        jax.random.fold_in(key, 1), (d,))))
    q = quantize_linear(w, bits, jax.random.fold_in(key, 2),
                        x_col_norms=col_norms, outlier_frac=0.01)
    x = jax.random.normal(jax.random.fold_in(key, 3), (7, d))
    y_apply = q.apply(x)
    y_recon = x @ reconstruct_weight(q)
    np.testing.assert_allclose(y_apply, y_recon, rtol=2e-3, atol=2e-3)


def test_quantization_error_reasonable():
    d, c, bits = 512, 64, 4
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (d, c))
    q = quantize_linear(w, bits, jax.random.fold_in(key, 1))
    w_hat = reconstruct_weight(q)
    rel = float(jnp.linalg.norm(w - w_hat) / jnp.linalg.norm(w))
    assert rel < 0.15


def test_outliers_help_with_spiky_inputs():
    """Column-outlier excluding should reduce error when a few input dims
    carry much larger activations."""
    d, c, bits = 256, 32, 2
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (d, c))
    col_norms = np.ones(d)
    col_norms[:3] = 100.0                   # dims 0..2 are hot
    x = jax.random.normal(jax.random.fold_in(key, 1), (64, d))
    x = x.at[:, :3].mul(100.0)
    ref = x @ w
    q_no = quantize_linear(w, bits, jax.random.fold_in(key, 2),
                           outlier_frac=0.0)
    q_out = quantize_linear(w, bits, jax.random.fold_in(key, 2),
                            x_col_norms=col_norms, outlier_frac=0.02)
    e_no = float(jnp.linalg.norm(q_no.apply(x) - ref))
    e_out = float(jnp.linalg.norm(q_out.apply(x) - ref))
    assert e_out < e_no


def test_centralization_helps_shifted_weights():
    d, c, bits = 256, 32, 2
    key = jax.random.PRNGKey(4)
    base = jax.random.normal(key, (d, 1))
    w = base + 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (d, c))
    q_c = quantize_linear(w, bits, jax.random.fold_in(key, 2), centralize=True)
    q_n = quantize_linear(w, bits, jax.random.fold_in(key, 2), centralize=False)
    e_c = float(jnp.linalg.norm(reconstruct_weight(q_c) - w))
    e_n = float(jnp.linalg.norm(reconstruct_weight(q_n) - w))
    assert e_c < e_n


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 5, 8])
def test_packing_roundtrip(bits):
    codes = jax.random.randint(jax.random.PRNGKey(0), (301, 17), 0,
                               1 << bits).astype(jnp.uint8)
    p = packing.pack_codes(codes, bits)
    u = packing.unpack_codes(p, bits, 301)
    assert (u == codes).all()
    if bits in (1, 2, 4):
        assert p.shape[0] == -(-301 // (8 // bits))


def test_grouped_apply_matches_per_expert():
    e, d, c = 4, 128, 48
    key = jax.random.PRNGKey(5)
    w = jax.random.normal(key, (e, d, c))
    qg = quantize_grouped(w, 4, jax.random.fold_in(key, 1))
    x = jax.random.normal(jax.random.fold_in(key, 2), (e, 5, d))
    y = qg.apply(x)
    assert y.shape == (e, 5, c)
    rel = float(jnp.linalg.norm(y - jnp.einsum("ecd,edf->ecf", x, w))
                / jnp.linalg.norm(jnp.einsum("ecd,edf->ecf", x, w)))
    assert rel < 0.15


def test_overhead_bits_accounting():
    w = jax.random.normal(jax.random.PRNGKey(6), (256, 64))
    q = quantize_linear(w, 4, jax.random.PRNGKey(7),
                        x_col_norms=np.ones(256), outlier_frac=0.01)
    ov = q.overhead_bits()
    assert ov > 0
    # overhead should be small vs the 4-bit payload
    assert ov < 0.6 * 4 * 256 * 64
