"""Prefix-cache correctness: content-addressed KV block reuse must be
invisible to outputs (token-identical greedy generations with caching on vs
a cold pool) across cache families, while refcounts/LRU eviction keep the
pool sound under allocation pressure and copy-on-write handles mid-block
divergence.  The jitted decode step must still trace exactly once whether
admissions hit or miss the cache."""
import dataclasses

import numpy as np
import pytest
from helpers import shared_prefix_requests, small_pool, tiny_model

from repro.serve import PagedServer, Request
from repro.serve.pool import BlockAllocator, PrefixCache

pytestmark = pytest.mark.tier2  # slow end-to-end serving suite

POOL = small_pool()
COLD = dataclasses.replace(POOL, prefix_cache=False)


# one arch per relevant cache family: full attention (caches), sliding
# window (ring blocks mutate in place -> bypass), MLA (per-slot latent
# state -> bypass); caching on must be output-invisible for all three
@pytest.mark.parametrize("arch", ["llama2-7b", "mixtral-8x7b",
                                  "deepseek-v2-236b"])
def test_greedy_identical_cache_on_vs_off(arch):
    cfg, params = tiny_model(arch)
    reqs = shared_prefix_requests(cfg)
    warm = PagedServer(cfg, params, POOL)
    got = warm.run([dataclasses.replace(r) for r in reqs])
    cold = PagedServer(cfg, params, COLD)
    ref = cold.run([dataclasses.replace(r) for r in reqs])
    for r in reqs:
        np.testing.assert_array_equal(
            got[r.rid].tokens, ref[r.rid].tokens,
            err_msg=f"{arch}: rid={r.rid}")
    if arch == "llama2-7b":
        assert warm.cacheable and warm.prefix_cache is not None
        assert warm.stats["prefill_tokens_saved"] > 0
        assert warm.stats["prefix_hit_rate"] > 0
        # prefill-token reduction must equal the tokens the cache served
        assert (warm.stats["prefill_tokens"]
                + warm.stats["prefill_tokens_saved"]
                == warm.stats["prompt_tokens"])
    else:
        # ring-window / MLA archs must bypass (blocks mutate or state is
        # per-slot), not serve stale KV
        assert warm.prefix_cache is None


def test_refcounts_drain_and_survive_sharing():
    """Blocks shared by concurrent requests are released exactly once per
    owner: after the run every block is free-or-cached-idle again."""
    cfg, params = tiny_model("llama2-7b")
    engine = PagedServer(cfg, params, POOL)
    engine.run(shared_prefix_requests(cfg))
    a = engine.allocator
    assert a.free_blocks == a.num_blocks - 1
    assert not a._ref                           # no leaked references
    assert a.cached_idle_blocks == len(engine.prefix_cache)


def test_eviction_under_pressure_before_admission_fails():
    """A pool whose blocks are all parked in the prefix cache must shrink
    the cache (LRU first) to admit a new request rather than deadlock."""
    cfg, params = tiny_model("llama2-7b")
    rng = np.random.default_rng(9)
    # arena fits exactly one request; request 1's cached blocks occupy it
    pool = dataclasses.replace(POOL, max_slots=1, num_blocks=9)
    engine = PagedServer(cfg, params, pool)
    engine.run([Request(rid=0, prompt=rng.integers(0, cfg.vocab, 16)
                        .astype(np.int32), max_new=4)])
    assert engine.allocator.cached_idle_blocks > 0
    p1 = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    got = engine.run([Request(rid=1, prompt=p1, max_new=4)])
    assert engine.prefix_cache.evictions > 0
    cold = PagedServer(cfg, params,
                       dataclasses.replace(pool, prefix_cache=False))
    ref = cold.run([Request(rid=1, prompt=p1, max_new=4)])
    np.testing.assert_array_equal(got[1].tokens, ref[1].tokens)


def test_cow_divergence_mid_block():
    """A prompt that diverges mid-block from a cached sequence reuses the
    matching token prefix via a private copy-on-write clone, and the cached
    original stays intact for later exact hits."""
    cfg, params = tiny_model("llama2-7b")
    rng = np.random.default_rng(5)
    base = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    div = base.copy()
    div[14:] = (div[14:] + 1) % cfg.vocab       # diverges inside block 3
    engine = PagedServer(cfg, params, POOL)
    r_base = engine.run([Request(rid=0, prompt=base, max_new=6)])
    saved0 = engine.stats["prefill_tokens_saved"]
    r_div = engine.run([Request(rid=1, prompt=div, max_new=6)])
    assert engine.stats.get("prefix_cow", 0) >= 1
    # 3 full blocks + 2 tokens of block 3 matched
    assert engine.stats["prefill_tokens_saved"] - saved0 == 14
    cold = PagedServer(cfg, params, COLD)
    ref = cold.run([Request(rid=1, prompt=div, max_new=6)])
    np.testing.assert_array_equal(r_div[1].tokens, ref[1].tokens)
    # the original sequence still hits its own (unclobbered) chain in full
    r_again = engine.run([Request(rid=2, prompt=base, max_new=6)])
    np.testing.assert_array_equal(r_again[2].tokens, r_base[0].tokens)


def test_decode_trace_count_one_under_hits_and_misses():
    cfg, params = tiny_model("llama2-7b")
    engine = PagedServer(cfg, params, POOL)
    engine.run(shared_prefix_requests(cfg))                  # misses + hits
    engine.run(shared_prefix_requests(cfg, seed=4))          # fresh misses
    engine.run(shared_prefix_requests(cfg))                  # near-full hits
    assert engine.stats["prefill_tokens_saved"] > 0
    assert engine.decode_trace_count == 1, (
        f"paged decode step retraced {engine.decode_trace_count} times")


# ------------------------------------------------------- host-side units


def test_prefix_cache_match_and_partial():
    c = PrefixCache(block_size=4)
    toks = list(range(1, 13))                   # blocks [1..4] [5..8] [9..12]
    h0 = c.register(c.ROOT, toks[0:4], 3)
    h1 = c.register(h0, toks[4:8], 7)
    # full-prefix lookup, capped below the second block boundary
    hits, parent, cached, cow = c.match(np.asarray(toks), 7)
    assert hits == [3] and parent == h0 and cached == 7 and cow == 7
    # exact full-block chain
    hits, parent, cached, cow = c.match(np.asarray(toks), 8)
    assert hits == [3, 7] and parent == h1 and cached == 8 and cow is None
    # divergence inside block 1 -> partial match against block 7's tokens
    div = toks[:6] + [99, 98, 97, 96]
    hits, _, cached, cow = c.match(np.asarray(div), 9)
    assert hits == [3] and cached == 6 and cow == 7
    # first content wins: re-registering the same chain keeps block 3
    assert c.register(c.ROOT, toks[0:4], 11) == h0
    assert c.match(np.asarray(toks), 4)[0] == [3]


def test_match_rejects_hash_collisions():
    """A chain_hash collision must not serve another sequence's KV: the
    stored token tuple is compared, not just the 64-bit hash."""
    c = PrefixCache(block_size=4)
    c.chain_hash = lambda parent, tokens: 0     # adversarial: everything collides
    c.register(c.ROOT, [1, 2, 3, 4], 5)
    hits, parent, cached, cow = c.match(np.asarray([9, 9, 9, 9, 9]), 4)
    assert hits == [] and cached == 0 and cow is None
    # the genuine sequence still matches through the colliding hash
    assert c.match(np.asarray([1, 2, 3, 4, 9]), 4)[0] == [5]


def test_refcounted_allocator_lru_eviction_order():
    cache = PrefixCache(block_size=4)
    a = BlockAllocator(6, cache=cache)
    got = a.alloc(5)                            # whole arena (1..5)
    assert a.alloc(1) is None
    h = cache.ROOT
    for i, b in enumerate(got):
        h = cache.register(h, [i] * 4, b)
    for b in got:                               # park all five in the LRU
        a.decref(b)
    assert a.free_blocks == 5 and a.cached_idle_blocks == 5
    # a prefix hit revives a block from the LRU instead of evicting it
    a.incref(got[0])
    assert a.cached_idle_blocks == 4
    # allocation pressure evicts in LRU (insertion) order: got[1] first
    fresh = a.alloc(1)
    assert fresh == [got[1]]
    assert cache.evictions == 1
    assert not cache.contains_block(got[1])
    # releasing a no-longer-cached block returns it to the free list
    a.decref(fresh[0])
    a.decref(got[0])
    assert a.free_blocks == 5
