"""Extended RaBitQ properties: the paper's eq. 11 error bound, approximate
unbiasedness, and monotone improvement in bits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import hadamard as h
from repro.core import rabitq


def _rotated_weights(key, d, c):
    w = jax.random.normal(key, (d, c))
    s = h.rademacher(jax.random.fold_in(key, 1), d)
    return h.rht(w, s, axis=0)


@settings(deadline=None, max_examples=12)
@given(bits=st.sampled_from([1, 2, 3, 4, 6, 8]),
       d=st.sampled_from([256, 1024]),
       seed=st.integers(0, 2**31 - 1))
def test_error_bound_eq11(bits, d, seed):
    """|<x,w> - est| < C/(sqrt(d) 2^b) ||x|| ||w|| for ~99.9% of entries."""
    key = jax.random.PRNGKey(seed)
    w = _rotated_weights(key, d, 48)
    q = rabitq.quantize(w, bits)
    x = jax.random.normal(jax.random.fold_in(key, 2), (32, d))
    est = rabitq.estimate_matmul(x, q)
    ref = x @ w
    scale = (jnp.linalg.norm(x, axis=1)[:, None]
             * jnp.linalg.norm(w, axis=0)[None, :])
    rel = np.asarray(jnp.abs(est - ref) / scale)
    bound = rabitq.C_ERROR / (np.sqrt(d) * 2 ** bits)
    assert (rel < bound).mean() > 0.985, (rel.max(), bound)


def test_near_unbiased():
    d, c = 1024, 64
    key = jax.random.PRNGKey(0)
    w = _rotated_weights(key, d, c)
    q = rabitq.quantize(w, 2)
    x = jax.random.normal(jax.random.fold_in(key, 3), (256, d))
    err = np.asarray(rabitq.estimate_matmul(x, q) - x @ w)
    scale = float(np.abs(np.asarray(x @ w)).std())
    assert abs(err.mean()) < 0.02 * scale


def test_more_bits_less_error():
    d, c = 512, 32
    w = _rotated_weights(jax.random.PRNGKey(5), d, c)
    x = jax.random.normal(jax.random.PRNGKey(6), (16, d))
    errs = []
    for bits in (1, 2, 4, 8):
        q = rabitq.quantize(w, bits)
        errs.append(float(jnp.abs(rabitq.estimate_matmul(x, q) - x @ w).mean()))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < errs[0] / 20


def test_codes_in_range():
    for bits in (1, 3, 8):
        w = _rotated_weights(jax.random.PRNGKey(7), 128, 8)
        q = rabitq.quantize(w, bits)
        assert int(q.codes.max()) <= (1 << bits) - 1
        assert q.codes.dtype == jnp.uint8


def test_dequantize_matches_estimator():
    w = _rotated_weights(jax.random.PRNGKey(8), 256, 16)
    q = rabitq.quantize(w, 4)
    x = jax.random.normal(jax.random.PRNGKey(9), (8, 256))
    np.testing.assert_allclose(x @ rabitq.dequantize(q),
                               rabitq.estimate_matmul(x, q), rtol=2e-4,
                               atol=2e-4)


def test_invalid_bits():
    with pytest.raises(ValueError):
        rabitq.quantize(jnp.ones((8, 4)), 9)
