"""Checkpointing (atomicity, keep-N, resume, elastic re-mesh) and the
fault-tolerant loop (injected failures, straggler watchdog)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime.fault import FaultTolerantLoop, LoopConfig


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
            "lst": [jnp.ones((3,)), jnp.zeros((2, 2))]}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(7, t, {"next_step": 8})
    restored, extra = mgr.restore(7, t)
    assert extra["next_step"] == 8
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(a, b)


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(6):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [4, 5]


def test_milestones_protected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1, milestone_every=4)
    for s in range(6):
        mgr.save(s, _tree())
    assert 0 in mgr.all_steps() and 4 in mgr.all_steps()


def test_no_tmp_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    assert not [f for f in os.listdir(tmp_path) if f.startswith("tmp.")]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(3, _tree())
    mgr.wait()
    assert mgr.latest_step() == 3
    restored, _ = mgr.restore(3, _tree(1))
    np.testing.assert_array_equal(restored["a"], _tree()["a"])


def test_elastic_remesh(tmp_path):
    """Save unsharded, restore with an explicit placement fn — the elastic
    re-mesh path (host arrays -> any mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(1, t)
    from repro.launch.mesh import _make_mesh
    mesh = _make_mesh((1, 1), ("data", "model"))
    def place(host_arr, like):
        spec = P(*([None] * host_arr.ndim))
        return jax.device_put(host_arr, NamedSharding(mesh, spec))
    restored, _ = mgr.restore(1, t, sharding_fn=place)
    assert isinstance(restored["a"].sharding, NamedSharding)
    np.testing.assert_array_equal(restored["a"], t["a"])


# ------------------------------------------------------- fault-tolerant loop


def _counter_loop(tmp_path, inject=None, cfg=None):
    mgr = CheckpointManager(str(tmp_path), keep=3)

    def step(state, batch):
        return state + batch, {"loss": float(state)}

    return FaultTolerantLoop(step, mgr,
                             cfg or LoopConfig(ckpt_every=5, max_retries=1),
                             inject_failure=inject), mgr


def test_loop_runs_and_checkpoints(tmp_path):
    loop, mgr = _counter_loop(tmp_path)
    state = loop.run(jnp.float32(0.0), lambda s: 1.0, 12)
    assert float(state) == 12.0
    assert mgr.latest_step() == 12


def test_loop_recovers_from_injected_failure(tmp_path):
    fails = {7: 3}  # step 7 fails 3 times -> exceeds retries -> restore

    def inject(step):
        if fails.get(step, 0) > 0:
            fails[step] -= 1
            return True
        return False

    loop, mgr = _counter_loop(tmp_path, inject)
    state = loop.run(jnp.float32(0.0), lambda s: 1.0, 12)
    assert float(state) == 12.0      # deterministic despite failure/restore
    assert loop.stats.retries >= 2


def test_loop_resume_from_checkpoint(tmp_path):
    loop, mgr = _counter_loop(tmp_path)
    loop.run(jnp.float32(0.0), lambda s: 1.0, 10)
    # new loop instance (simulated process restart)
    loop2, _ = _counter_loop(tmp_path)
    state, start = loop2.maybe_resume(jnp.float32(0.0))
    assert start == 10
    state = loop2.run(state, lambda s: 1.0, 15, start_step=start)
    assert float(state) == 15.0


def test_straggler_watchdog(tmp_path):
    import time
    mgr = CheckpointManager(str(tmp_path))

    def step(state, batch):
        if 8 <= batch < 10:
            time.sleep(0.05)
        return state + 1, {}

    loop = FaultTolerantLoop(step, mgr, LoopConfig(
        ckpt_every=100, straggler_factor=3.0, straggler_window=8,
        straggler_patience=2))
    loop.run(jnp.float32(0.0), lambda s: s, 12)
    assert loop.stats.straggler_events >= 1
