"""Tensor-parallel serving (runtime/tp.py, DESIGN.md §11).

The correctness bar is exact: every sharded weight is column-sharded and
the TP boundary is an all_gather of disjoint slices (never a psum of
partial products), so each output column is computed by exactly one shard
with the same float ops as the single-device engine — greedy outputs must
be *token-identical* at TP=2 vs TP=1, and the mesh-aware decode step must
still trace exactly once under request churn.

TP=2 needs two devices, and ``--xla_force_host_platform_device_count``
must be set before jax initializes — the pytest process already holds a
1-device jax, so every TP scenario runs in a fresh subprocess (the
``_DRIVER`` script below) that forces a 2-device host, runs both engines,
and reports mismatches / trace counts / plan flags / arena shardings as
JSON.  In-process tests cover what doesn't need a second device: mesh
validation, the TP plan predicates, the gate|up interleaving permutation,
and the shape-driven no-op of the gather helpers.
"""
from __future__ import annotations

import json
import os
import re
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.configs import registry
from repro.runtime import tp as tpmod

_ROOT = Path(__file__).resolve().parent.parent


# ----------------------------------------------------- in-process (tier1)


def test_make_host_mesh_validates_tp():
    """tp must divide the device count (1 on the plain test host)."""
    from repro.launch.mesh import make_host_mesh
    m = make_host_mesh()                       # tp=1 always works
    assert m.shape["model"] == 1
    with pytest.raises(ValueError):
        make_host_mesh(tp=0)
    with pytest.raises(ValueError):
        make_host_mesh(tp=2)                   # 1 host device


def test_plan_predicates():
    """Divisibility decides what shards; attention is all-or-nothing in
    (n_heads, n_kv) so the GQA group ratio matches the sharded arena."""
    llama = registry.get_tiny("llama2-7b")     # heads 4, kv 4, ff 384
    p = tpmod.plan_for(llama, 2)
    assert p.attn and p.ffn and p.lm_head and not p.moe
    yi = registry.get_tiny("yi-34b")           # heads 7, kv 1 -> replicate
    p = tpmod.plan_for(yi, 2)
    assert not p.attn and p.ffn
    mix = registry.get_tiny("mixtral-8x7b")    # moe: expert columns shard
    p = tpmod.plan_for(mix, 2)
    assert p.attn and p.moe and not p.ffn
    # TP=1 is the degenerate plan: nothing shards
    assert not any([f for k, f in tpmod.plan_for(llama, 1).asdict().items()
                    if k != "tp"])


def test_glu_perm_interleaves_gate_up():
    """The placement permutation must put [gate_i | up_i] contiguously per
    shard so the local split(gu, 2) is correct and the gathered hidden
    state lands back in natural column order."""
    two_f, tp = 24, 2
    perm = tpmod._glu_perm(two_f, tp)
    f, fl = two_f // 2, two_f // 2 // tp
    assert sorted(perm.tolist()) == list(range(two_f))
    for i in range(tp):
        shard = perm[i * 2 * fl:(i + 1) * 2 * fl]
        # first half of the shard = gate columns, second half = up columns,
        # both the i-th contiguous slice of the full gate/up ranges
        assert shard[:fl].tolist() == list(range(i * fl, (i + 1) * fl))
        assert shard[fl:].tolist() == list(range(f + i * fl, f + (i + 1) * fl))


def test_gather_helpers_are_shape_driven_noops():
    """At full width the helpers return their input unchanged — no axis
    name needed — which is exactly why TP=1 shares the sharded code path."""
    x = np.zeros((3, 1, 4, 8), np.float32)
    assert tpmod.gather_heads(x, 4) is x
    y = np.zeros((3, 16), np.float32)
    assert tpmod.gather_cols(y, 16) is y


# ----------------------------------------- subprocess scenarios (2 devices)

_DRIVER = r"""
import json, sys
import jax, jax.numpy as jnp, numpy as np
from helpers import (tiny_model, small_pool, mixed_requests,
                     shared_prefix_requests)
from repro.launch.mesh import make_host_mesh
from repro.serve import PagedServer

def quantized(cfg, params, dual=False):
    from repro.core import calibrate as cal
    from repro.core import pipeline as pipe
    from repro.models import transformer as tf
    toks = cal.zero_shot_tokens(cfg.vocab, 32)
    stats = cal.calibrate(
        lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
        params, [{"tokens": jnp.asarray(toks)}])
    if dual:
        tq, _, dq, _ = pipe.quantize_model_dual(
            cfg, params, stats, 4.0, 2.2, jax.random.PRNGKey(1),
            bit_choices=(1, 2, 3, 4, 5), n_candidates=2)
        return tq, dq
    q, _ = pipe.quantize_model(cfg, params, stats, 4.0, jax.random.PRNGKey(1),
                               bit_choices=(2, 3, 4, 5), n_candidates=2)
    return q, None

def run(scenario):
    arch = dict(llama2="llama2-7b", llama2_quant="llama2-7b",
                prefix="llama2-7b", speculative="llama2-7b",
                mixtral="mixtral-8x7b", gqa="llama3.2-3b",
                gqa_kernel="llama3.2-3b", yi="yi-34b")[scenario]
    cfg, params = tiny_model(arch)
    kw, pool, reqs_fn = {}, small_pool(), mixed_requests
    if scenario in ("llama2_quant", "prefix"):
        params, _ = quantized(cfg, params)
    if scenario == "prefix":
        pool = small_pool(prefix_cache=True)
        reqs_fn = shared_prefix_requests
    if scenario == "speculative":
        params, draft = quantized(cfg, params, dual=True)
        kw = dict(draft_params=draft, speculate=2)
    if scenario == "gqa_kernel":
        kw = dict(paged_kernel=True)
    reqs = reqs_fn(cfg)
    e1 = PagedServer(cfg, params, pool, **kw)
    r1 = e1.run(list(reqs))
    e2 = PagedServer(cfg, params, pool, mesh=make_host_mesh(tp=2), **kw)
    r2 = e2.run(list(reqs))
    arena = next((l for l in jax.tree.leaves(e2.caches)
                  if getattr(l, "ndim", 0) == 5), None)
    return {
        "devices": len(jax.devices()),
        "mismatches": sum(1 for k in r1
                          if r1[k].tokens.tolist() != r2[k].tokens.tolist()),
        "n_results": len(r1),
        "decode_traces_tp2": e2.decode_trace_count,
        "verify_traces_tp2": e2.verify_trace_count,
        "plan": e2.tp_plan.asdict(),
        "arena_spec": "" if arena is None else str(arena.sharding.spec),
        "prefix_hit_rate_tp2": e2.stats.get("prefix_hit_rate", -1.0),
        "acceptance_tp1": e1.stats.get("acceptance_rate", -1.0),
        "acceptance_tp2": e2.stats.get("acceptance_rate", -1.0),
    }

print(json.dumps(run(sys.argv[1])))
"""


def _run_tp_scenario(scenario: str) -> dict:
    env = dict(os.environ)
    # Scrub any inherited device-count flag first (importing launch.dryrun
    # anywhere in the pytest process exports a 512-device XLA_FLAGS into
    # os.environ, and with duplicate flags the last one wins).
    inherited = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                       env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (inherited
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.pathsep.join(
        [str(_ROOT / "src"), str(_ROOT / "tests"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", _DRIVER, scenario],
                          capture_output=True, text=True, env=env,
                          cwd=str(_ROOT), timeout=900)
    assert proc.returncode == 0, f"{scenario} driver failed:\n{proc.stderr}"
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["devices"] == 2
    assert out["n_results"] > 0
    return out


def test_tp2_parity_llama2_and_trace_count():
    """The acceptance bar: TP=2 greedy outputs token-identical to TP=1 on
    the churn workload, everything sharded, ONE decode trace."""
    out = _run_tp_scenario("llama2")
    assert out["mismatches"] == 0
    assert out["decode_traces_tp2"] == 1
    assert out["plan"] == dict(tp=2, attn=True, ffn=True, moe=False,
                               shared=False, lm_head=True)
    assert "model" in out["arena_spec"]        # KV arena head axis sharded


@pytest.mark.tier2
def test_tp2_parity_llama2_quantized():
    """Sharding the *quantized* artifact (packed codes + side info sliced
    by column) is the distinctive part — parity must hold there too."""
    out = _run_tp_scenario("llama2_quant")
    assert out["mismatches"] == 0
    assert out["decode_traces_tp2"] == 1


@pytest.mark.tier2
def test_tp2_parity_mixtral_windowed_moe():
    """Windowed attention + MoE: expert columns shard, dense-ffn flag off,
    ring-buffered arena still sharded by KV head."""
    out = _run_tp_scenario("mixtral")
    assert out["mismatches"] == 0
    assert out["decode_traces_tp2"] == 1
    assert out["plan"]["moe"] and not out["plan"]["ffn"]
    assert "model" in out["arena_spec"]


@pytest.mark.tier2
def test_tp2_parity_gqa():
    """GQA (6 heads / 2 KV heads): the group ratio must stay consistent
    between the sharded q heads and the sharded arena."""
    out = _run_tp_scenario("gqa")
    assert out["mismatches"] == 0
    assert out["plan"]["attn"]


@pytest.mark.tier2
def test_tp2_parity_gqa_pallas_kernel():
    """The Pallas flash-decode kernel runs per-shard over the sharded
    arena (interpret mode on CPU) and must agree with TP=1."""
    out = _run_tp_scenario("gqa_kernel")
    assert out["mismatches"] == 0


@pytest.mark.tier2
def test_tp2_nondivisible_heads_degrade_to_replication():
    """yi-style head counts (7 heads, 1 KV head) don't divide: attention
    replicates (arena included) while the FFN still shards — and parity
    holds through the mixed plan."""
    out = _run_tp_scenario("yi")
    assert out["mismatches"] == 0
    assert not out["plan"]["attn"] and out["plan"]["ffn"]
    assert "model" not in out["arena_spec"]    # replicated arena


@pytest.mark.tier2
def test_tp2_prefix_cache_parity():
    """Prefix caching is host-side replicated state; hits/COW must not
    perturb sharded outputs, and the hit rate must survive TP."""
    out = _run_tp_scenario("prefix")
    assert out["mismatches"] == 0
    assert out["prefix_hit_rate_tp2"] > 0.0


@pytest.mark.tier2
def test_tp2_speculative_parity():
    """Greedy self-speculative decoding on the sharded engine: emitted
    tokens are target argmaxes, so TP=2 must be token-identical, with a
    sane acceptance rate on both engines."""
    out = _run_tp_scenario("speculative")
    assert out["mismatches"] == 0
    assert 0.0 <= out["acceptance_tp2"] <= 1.0
    assert out["acceptance_tp1"] == pytest.approx(out["acceptance_tp2"])
