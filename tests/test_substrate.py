"""Data loader, optimizer, schedules, gradient compression, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.data import LMBatchLoader, make_corpus_tokens
from repro.optim import adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule
from repro.runtime import sharding as shd
from repro.runtime.compression import (ErrorFeedback,
                                       compress_decompress_grads)


# ----------------------------------------------------------------- data


def test_loader_deterministic_and_resumable():
    toks = make_corpus_tokens(256, 2000)
    l1 = LMBatchLoader(toks, 4, 32)
    b0, b1 = l1.next_batch(), l1.next_batch()
    l2 = LMBatchLoader(toks, 4, 32)
    l2.load_state_dict({"step": 1})
    np.testing.assert_array_equal(l2.next_batch(), b1)
    assert not np.array_equal(b0, b1)


def test_loader_host_sharding_disjoint_streams():
    toks = make_corpus_tokens(256, 2000)
    a = LMBatchLoader(toks, 4, 32, host_index=0, host_count=2).next_batch()
    b = LMBatchLoader(toks, 4, 32, host_index=1, host_count=2).next_batch()
    assert not np.array_equal(a, b)


def test_corpus_learnable():
    toks = make_corpus_tokens(256, 500)
    assert len(toks) > 5000
    assert toks.max() < 256


# ---------------------------------------------------------------- optim


def test_adamw_optimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, lr=0.1,
                                      weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(s, 1.0, 10, 100)) for s in range(100)]
    assert lrs[0] < lrs[9]                  # warmup
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[-1] < 0.2                    # decayed to floor


# ----------------------------------------------------------- compression


def test_int8_compression_small_error():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 64))}
    gc = compress_decompress_grads(g)
    rel = float(jnp.linalg.norm(gc["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.01


def test_error_feedback_reduces_bias():
    key = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(key, (64,)) * 1e-3 + 1e-5}
    ef = ErrorFeedback(g)
    total_naive = jnp.zeros(64)
    total_ef = jnp.zeros(64)
    for _ in range(50):
        total_naive += compress_decompress_grads(g)["w"]
        total_ef += ef.apply(g)["w"]
    true = g["w"] * 50
    assert float(jnp.linalg.norm(total_ef - true)) <= \
        float(jnp.linalg.norm(total_naive - true)) + 1e-6


# --------------------------------------------------------------- sharding


def _mesh(shape=(16, 16), axes=("data", "model")):
    try:
        return AbstractMesh(shape, axes)
    except TypeError:  # jax < 0.5: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(axes, shape)))


def _check_specs(specs, tree, mesh):
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_t = jax.tree.leaves(tree)
    assert len(flat_s) == len(flat_t)
    for sp, leaf in zip(flat_s, flat_t):
        assert isinstance(sp, P)
        for i, ax in enumerate(sp):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert leaf.shape[i] % size == 0, (sp, leaf.shape)


@pytest.mark.parametrize("arch", ["yi-34b", "deepseek-v2-236b",
                                  "mixtral-8x7b", "rwkv6-3b",
                                  "whisper-large-v3"])
@pytest.mark.parametrize("serve", [False, True])
def test_param_specs_divisible_on_production_mesh(arch, serve):
    from repro.configs.registry import get_config
    from repro.models import transformer as tf
    cfg = get_config(arch)
    sds = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0),
                                                dtype=jnp.bfloat16))
    mesh = _mesh()
    specs = shd.param_specs(sds, mesh, serve=serve)
    _check_specs(specs, sds, mesh)


def test_param_specs_multipod():
    from repro.configs.registry import get_config
    from repro.models import transformer as tf
    cfg = get_config("internlm2-1.8b")
    sds = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    mesh = _mesh((2, 16, 16), ("pod", "data", "model"))
    specs = shd.param_specs(sds, mesh)
    _check_specs(specs, sds, mesh)


def test_big_weights_actually_sharded():
    """Guard against rules silently degrading to full replication."""
    from repro.configs.registry import get_config
    from repro.models import transformer as tf
    cfg = get_config("yi-34b")
    sds = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    specs = shd.param_specs(sds, _mesh(), serve=False)
    wq_spec = specs["layers"][0]["attn"]["wq"]
    assert wq_spec == P(None, "data", "model")
    moe_cfg = get_config("deepseek-v2-236b")
    sds2 = jax.eval_shape(lambda: tf.init_params(moe_cfg,
                                                 jax.random.PRNGKey(0)))
    wi_spec = shd.param_specs(sds2, _mesh(), serve=False)["layers"][0]["moe"]["wi"]
    assert wi_spec[1] == "model"            # expert parallelism
