"""Import hypothesis if available, else a minimal fixed-seed fallback.

Tier-1 tests use ``given``/``settings``/``st.integers``/``st.sampled_from``
for property-style sweeps.  The real hypothesis (requirements-dev.txt) is
strictly better — shrinking, coverage-guided example generation — but its
absence must not kill collection: this shim replays a deterministic,
fixed-seed sample of each strategy so the properties still get exercised.

Usage in test modules (tests/ is on sys.path via pytest rootdir insertion):

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _SEED = 0x5AA9A  # fixed: examples must be identical run-to-run

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # rng -> value

    class st:  # noqa: N801 — mimics `from hypothesis import strategies as st`
        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(_SEED)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    example = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **example, **kwargs)
            # pytest must not see the given-supplied params as fixtures
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            del wrapper.__dict__["__wrapped__"]
            return wrapper
        return deco

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
