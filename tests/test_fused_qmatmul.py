"""Fused RHT+qmatmul decode path: kernel parity vs the unfused composition
(practical_rht -> quantized_matmul_ref), dispatch paths, and the grouped/MoE
expert route (which must never unpack codes to a dense (E, d, c) buffer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hadamard as hcore
from repro.core import packing, rabitq
from repro.core.qlinear import quantize_grouped, quantize_linear
from repro.kernels.qmatmul import ops as qops
from repro.kernels.qmatmul.qmatmul import rht_quantized_matmul_pallas
from repro.kernels.qmatmul.ref import (quantized_matmul_ref,
                                       rht_quantized_matmul_ref)


def _quantized_layer(key, d, c, bits):
    """Packed codes + rescale + shared signs for a random (d, c) weight."""
    d_hat = hcore.largest_pow2_leq(d)
    s1 = hcore.rademacher(jax.random.fold_in(key, 1), d_hat)
    s2 = (hcore.rademacher(jax.random.fold_in(key, 2), d_hat)
          if d_hat != d else None)
    w = jax.random.normal(key, (d, c))
    q = rabitq.quantize(hcore.practical_rht(w, s1, s2, axis=0), bits)
    return packing.pack_codes(q.codes, bits), q.rescale, s1, s2


def _unfused(x, p, r, s1, s2, *, bits, d):
    xr = hcore.practical_rht(x.astype(jnp.float32), s1, s2, axis=-1)
    return quantized_matmul_ref(xr, p, r, bits=bits, d=d)


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("n,d,c", [
    (1, 256, 33),     # single-token decode, power-of-2 d
    (7, 300, 40),     # batched, non-power-of-2 d (overlapped Alg. 5 blocks)
    (16, 512, 96),
    (1, 4096, 16),    # single token, large d
    (5, 96, 24),      # tiny non-power-of-2 d
    (9, 300, 200),    # c > bc: multiple column tiles (j grid dim + epilogue)
])
def test_fused_kernel_matches_unfused(bits, n, d, c):
    key = jax.random.PRNGKey(bits * 1000 + d + n)
    p, r, s1, s2 = _quantized_layer(key, d, c, bits)
    x = jax.random.normal(jax.random.fold_in(key, 3), (n, d))
    ref = _unfused(x, p, r, s1, s2, bits=bits, d=d)
    out = rht_quantized_matmul_pallas(x, p, r, s1, s2, bits=bits, d=d,
                                      interpret=True)
    np.testing.assert_allclose(out, ref, rtol=1e-4,
                               atol=1e-4 * float(jnp.abs(ref).max() + 1))


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
def test_fused_ref_matches_unfused(bits):
    d, c = 300, 40
    key = jax.random.PRNGKey(bits)
    p, r, s1, s2 = _quantized_layer(key, d, c, bits)
    x = jax.random.normal(jax.random.fold_in(key, 3), (4, d))
    np.testing.assert_allclose(
        rht_quantized_matmul_ref(x, p, r, s1, s2, bits=bits, d=d),
        _unfused(x, p, r, s1, s2, bits=bits, d=d), rtol=1e-5, atol=1e-5)


def test_dispatch_paths_agree():
    """Forced pallas / forced ref / unfused toggle must all agree."""
    d, c, bits = 768, 48, 4
    key = jax.random.PRNGKey(0)
    p, r, s1, s2 = _quantized_layer(key, d, c, bits)
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 3, d))
    try:
        qops.set_forced_path("ref")
        y_ref = qops.rht_quantized_matmul(x, p, r, s1, s2, bits=bits, d=d)
        qops.set_forced_path("pallas")
        y_pal = qops.rht_quantized_matmul(x, p, r, s1, s2, bits=bits, d=d)
        with qops.fusion(False):
            y_unf = qops.rht_quantized_matmul(x, p, r, s1, s2, bits=bits, d=d)
    finally:
        qops.set_forced_path(None)
    assert y_ref.shape == (2, 3, c)
    np.testing.assert_allclose(y_ref, y_pal, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y_ref, y_unf, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("d", [128, 300])
@pytest.mark.parametrize("path", ["ref", "pallas"])
def test_grouped_fused_matches_per_expert(d, path):
    """vmapped fused kernel == per-expert unfused composition, and the
    QuantizedGrouped pytree holds only packed uint8 codes (no dense f32)."""
    e, c, bits = 3, 40, 4
    key = jax.random.PRNGKey(d)
    w = jax.random.normal(key, (e, d, c))
    qg = quantize_grouped(w, bits, jax.random.fold_in(key, 1))
    assert qg.packed.dtype == jnp.uint8
    assert qg.packed.shape == (e, packing.packed_rows(d, bits), c)
    x = jax.random.normal(jax.random.fold_in(key, 2), (e, 5, d))
    try:
        qops.set_forced_path(path)
        y = qg.apply(x)
    finally:
        qops.set_forced_path(None)
    expect = jnp.stack([
        _unfused(x[i], qg.packed[i], qg.rescale[i], qg.signs1, qg.signs2,
                 bits=bits, d=d) for i in range(e)])
    np.testing.assert_allclose(y, expect, rtol=1e-4,
                               atol=1e-4 * float(jnp.abs(expect).max() + 1))


def test_grouped_apply_never_unpacks_dense():
    """The jaxpr of QuantizedGrouped.apply must not materialize any
    (E, d, c)-shaped intermediate — codes travel packed into the kernel."""
    e, d, c, bits = 4, 256, 32, 4
    key = jax.random.PRNGKey(7)
    qg = quantize_grouped(jax.random.normal(key, (e, d, c)), bits,
                          jax.random.fold_in(key, 1))
    x = jax.random.normal(jax.random.fold_in(key, 2), (e, 6, d))
    try:
        qops.set_forced_path("pallas")
        jaxpr = jax.make_jaxpr(qg.apply)(x)
    finally:
        qops.set_forced_path(None)
    dense = [v for eqn in jaxpr.jaxpr.eqns for v in eqn.outvars
             if getattr(v.aval, "shape", None) == (e, d, c)]
    assert not dense, f"dense (E, d, c) intermediates found: {dense}"


@pytest.mark.parametrize("path", ["ref", "pallas"])
def test_qlinear_apply_with_tricks_across_paths(path):
    """Full QuantizedLinear.apply (outliers + centralization) through the
    fused dispatch agrees with the unfused toggle on the same path."""
    d, c, bits = 300, 32, 4
    key = jax.random.PRNGKey(9)
    w = jax.random.normal(key, (d, c))
    col_norms = np.abs(np.asarray(
        jax.random.normal(jax.random.fold_in(key, 1), (d,))))
    q = quantize_linear(w, bits, jax.random.fold_in(key, 2),
                        x_col_norms=col_norms, outlier_frac=0.01)
    x = jax.random.normal(jax.random.fold_in(key, 3), (5, d))
    try:
        qops.set_forced_path(path)
        y_fused = q.apply(x)
        with qops.fusion(False):
            y_unfused = q.apply(x)
    finally:
        qops.set_forced_path(None)
    np.testing.assert_allclose(y_fused, y_unfused, rtol=1e-4,
                               atol=1e-4 * float(jnp.abs(y_unfused).max() + 1))


def test_fusion_context_scoped():
    """fusion() nests/unwinds; the deprecated set_fused shim is gone."""
    assert qops.fused_enabled()
    with qops.fusion(False):
        assert not qops.fused_enabled()
        with qops.fusion(True):
            assert qops.fused_enabled()
        assert not qops.fused_enabled()
    assert qops.fused_enabled()
    assert not hasattr(qops, "set_fused")


def test_single_token_decode_shape():
    """(B, 1, d) decode-shaped input through the fused dispatch."""
    d, c, bits = 512, 64, 2
    key = jax.random.PRNGKey(11)
    p, r, s1, s2 = _quantized_layer(key, d, c, bits)
    x = jax.random.normal(jax.random.fold_in(key, 3), (3, 1, d))
    try:
        qops.set_forced_path("pallas")
        y = qops.rht_quantized_matmul(x, p, r, s1, s2, bits=bits, d=d)
    finally:
        qops.set_forced_path(None)
    assert y.shape == (3, 1, c)
    ref = _unfused(x.reshape(3, d), p, r, s1, s2, bits=bits, d=d)
    np.testing.assert_allclose(y.reshape(3, c), ref, rtol=1e-4,
                               atol=1e-4 * float(jnp.abs(ref).max() + 1))
