"""AllocateBits: DP optimality vs brute force (Alg. 4), GCD trick, budgets."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import allocate


@settings(deadline=None, max_examples=30)
@given(n=st.integers(2, 6), seed=st.integers(0, 2**31 - 1),
       avg=st.sampled_from([2.0, 3.5, 5.0]))
def test_dp_matches_brute_force(n, seed, avg):
    rng = np.random.default_rng(seed)
    alphas = rng.uniform(0.1, 20.0, n)
    m = (rng.integers(1, 9, n) * 64).tolist()
    budget = int(avg * sum(m))
    bits = [1, 2, 3, 4, 6, 8]
    dp = allocate.allocate_bits(alphas, m, budget, bits)
    bf = allocate.brute_force_allocate(alphas, m, budget, bits)
    assert abs(dp.objective - bf.objective) < 1e-9 * max(1, bf.objective)
    assert dp.total_bits <= budget


def test_budget_respected_and_sensitive_layers_win():
    # layer 0 is 100x more sensitive -> must get >= bits of layer 1
    res = allocate.allocate_bits([100.0, 1.0], [256, 256], 6 * 512,
                                 [1, 2, 3, 4, 6, 8])
    assert res.bits[0] >= res.bits[1]
    assert res.total_bits <= 6 * 512


def test_gcd_trick_reduces_problem():
    m = [4096 * 4096] * 8
    res = allocate.allocate_bits([1.0] * 8, m, 4 * sum(m), [2, 4, 8])
    assert res.gcd >= 4096 * 4096          # all m equal => gcd = m
    assert res.n_slots <= 8 * 8


def test_infeasible_budget_raises():
    with pytest.raises(ValueError):
        allocate.allocate_bits([1.0, 1.0], [128, 128], 100, [2, 4])


def test_equal_sensitivity_uniform_allocation():
    res = allocate.allocate_for_avg_bits([5.0] * 4, [512] * 4, 4.0,
                                         [1, 2, 3, 4, 5, 6, 7, 8])
    assert res.bits == [4, 4, 4, 4]


def test_coarsening_safeguard():
    # coprime sizes -> g = 1 -> slots would exceed cap -> coarsened budget
    m = [999983, 999979, 1000003]          # primes
    res = allocate.allocate_bits([1.0, 2.0, 3.0], m, 4 * sum(m), [2, 4, 8])
    assert res.total_bits <= 4 * sum(m)
    assert res.n_slots <= allocate._MAX_SLOTS
