"""AllocateBits: DP optimality vs brute force (Alg. 4), GCD trick, budgets."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import allocate


@settings(deadline=None, max_examples=30)
@given(n=st.integers(2, 6), seed=st.integers(0, 2**31 - 1),
       avg=st.sampled_from([2.0, 3.5, 5.0]))
def test_dp_matches_brute_force(n, seed, avg):
    rng = np.random.default_rng(seed)
    alphas = rng.uniform(0.1, 20.0, n)
    m = (rng.integers(1, 9, n) * 64).tolist()
    budget = int(avg * sum(m))
    bits = [1, 2, 3, 4, 6, 8]
    dp = allocate.allocate_bits(alphas, m, budget, bits)
    bf = allocate.brute_force_allocate(alphas, m, budget, bits)
    assert abs(dp.objective - bf.objective) < 1e-9 * max(1, bf.objective)
    assert dp.total_bits <= budget


def test_budget_respected_and_sensitive_layers_win():
    # layer 0 is 100x more sensitive -> must get >= bits of layer 1
    res = allocate.allocate_bits([100.0, 1.0], [256, 256], 6 * 512,
                                 [1, 2, 3, 4, 6, 8])
    assert res.bits[0] >= res.bits[1]
    assert res.total_bits <= 6 * 512


def test_gcd_trick_reduces_problem():
    m = [4096 * 4096] * 8
    res = allocate.allocate_bits([1.0] * 8, m, 4 * sum(m), [2, 4, 8])
    assert res.gcd >= 4096 * 4096          # all m equal => gcd = m
    assert res.n_slots <= 8 * 8


def test_infeasible_budget_raises():
    with pytest.raises(ValueError):
        allocate.allocate_bits([1.0, 1.0], [128, 128], 100, [2, 4])


def test_equal_sensitivity_uniform_allocation():
    res = allocate.allocate_for_avg_bits([5.0] * 4, [512] * 4, 4.0,
                                         [1, 2, 3, 4, 5, 6, 7, 8])
    assert res.bits == [4, 4, 4, 4]


def test_coarsening_safeguard():
    # coprime sizes -> g = 1 -> slots would exceed cap -> coarsened budget
    m = [999983, 999979, 1000003]          # primes
    res = allocate.allocate_bits([1.0, 2.0, 3.0], m, 4 * sum(m), [2, 4, 8])
    assert res.total_bits <= 4 * sum(m)
    assert res.n_slots <= allocate._MAX_SLOTS


@settings(deadline=None, max_examples=40)
@given(n=st.integers(2, 5), seed=st.integers(0, 2**31 - 1),
       avg=st.floats(1.5, 6.0))
def test_coarsened_dp_never_exceeds_budget(n, seed, avg):
    """Adversarial (coprime-ish) layer sizes under a tiny slot cap: the
    round-to-nearest slot costs under-count real bits, so without the
    verify/repair pass allocate_bits returned total_bits > budget (e.g.
    seed 18 overran a 36118-bit budget by 329 bits)."""
    old = allocate._MAX_SLOTS
    allocate._MAX_SLOTS = 50               # force the coarsened path
    try:
        rng = np.random.default_rng(seed)
        m = [int(x) for x in rng.integers(3, 4001, n)]
        alphas = rng.uniform(0.1, 20.0, n)
        budget = int(avg * sum(m))
        bits = [1, 2, 3, 4, 6, 8]
        if budget < bits[0] * sum(m):
            budget = bits[0] * sum(m)
        dp = allocate.allocate_bits(alphas, m, budget, bits)
        bf = allocate.brute_force_allocate(alphas, m, budget, bits)
        assert dp.total_bits <= budget     # the hard feasibility contract
        # the DP can be suboptimal under coarsened costs, never super-optimal
        assert dp.objective >= bf.objective - 1e-9 * max(1, bf.objective)
    finally:
        allocate._MAX_SLOTS = old


def test_avg_bits_on_directly_constructed_result():
    res = allocate.AllocationResult(bits=[4, 4], total_bits=4096, budget=5000,
                                    objective=0.0, gcd=1, n_slots=5000,
                                    total_params=1024)
    assert res.avg_bits == 4.0
    assert allocate.allocate_bits([1.0], [128], 512, [2, 4]).total_params == 128
