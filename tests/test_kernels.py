"""Pallas kernels vs pure-jnp oracles (interpret=True), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hadamard as hcore
from repro.core import packing, rabitq
from repro.kernels.hadamard.hadamard import rht_pallas
from repro.kernels.hadamard.ref import rht_ref
from repro.kernels.qmatmul.qmatmul import quantized_matmul_pallas
from repro.kernels.qmatmul.ref import quantized_matmul_ref
from repro.kernels.rabitq_quant.quantize import quantize_pallas


@pytest.mark.parametrize("bits,n,d,c", [
    (1, 5, 256, 33), (2, 33, 700, 130), (3, 9, 300, 50),
    (4, 64, 512, 96), (4, 1, 4096, 16), (8, 17, 1024, 64),
])
def test_qmatmul_kernel_vs_ref(bits, n, d, c):
    key = jax.random.PRNGKey(bits * 1000 + d)
    w = jax.random.normal(key, (d, c))
    q = rabitq.quantize(w, bits)
    p = packing.pack_codes(q.codes, bits)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    ref = quantized_matmul_ref(x, p, q.rescale, bits=bits, d=d)
    out = quantized_matmul_pallas(x, p, q.rescale, bits=bits, d=d,
                                  interpret=True)
    np.testing.assert_allclose(out, ref, rtol=1e-4,
                               atol=1e-4 * float(jnp.abs(ref).max() + 1))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qmatmul_kernel_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (512, 64))
    q = rabitq.quantize(w, 4)
    p = packing.pack_codes(q.codes, 4)
    x = jax.random.normal(jax.random.fold_in(key, 1), (16, 512)).astype(dtype)
    ref = quantized_matmul_ref(x.astype(jnp.float32), p, q.rescale,
                               bits=4, d=512)
    out = quantized_matmul_pallas(x, p, q.rescale, bits=4, d=512,
                                  interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, ref, rtol=tol,
                               atol=tol * float(jnp.abs(ref).max() + 1))


@pytest.mark.parametrize("n,d", [(16, 1024), (7, 4096), (3, 256), (1, 16384)])
def test_hadamard_kernel_vs_ref(n, d):
    key = jax.random.PRNGKey(d)
    x = jax.random.normal(key, (n, d))
    s = hcore.rademacher(jax.random.fold_in(key, 1), d)
    out = rht_pallas(x, s, interpret=True)
    ref = rht_ref(x, s)
    np.testing.assert_allclose(out, ref, atol=2e-4)


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("d,c", [(777, 91), (2048, 16)])
def test_rabitq_quant_kernel_vs_ref(bits, d, c):
    w = jax.random.normal(jax.random.PRNGKey(bits + d), (d, c))
    ck, rk = quantize_pallas(w, bits=bits, interpret=True)
    q = rabitq.quantize(w, bits)
    # exact code equality up to boundary ties (x.5 rounding under fused vs
    # unfused f32 arithmetic); mismatches must be rare and off-by-one
    diff = np.asarray(ck).astype(int) - np.asarray(q.codes).astype(int)
    assert np.abs(diff).max() <= 1
    assert (diff != 0).mean() < 5e-3
    np.testing.assert_allclose(rk, q.rescale, rtol=5e-3, atol=1e-5)


def test_ops_dispatch_paths():
    """The ops wrappers must agree across forced paths."""
    from repro.kernels.qmatmul import ops as qops
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (300, 40))
    q = rabitq.quantize(w, 4)
    p = packing.pack_codes(q.codes, 4)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 6, 300))
    try:
        qops.set_forced_path("ref")
        y_ref = qops.quantized_matmul(x, p, q.rescale, bits=4, d=300)
        qops.set_forced_path("pallas")
        y_pal = qops.quantized_matmul(x, p, q.rescale, bits=4, d=300)
    finally:
        qops.set_forced_path(None)
    assert y_ref.shape == (4, 6, 40)
    np.testing.assert_allclose(y_ref, y_pal, rtol=1e-4, atol=1e-4)
