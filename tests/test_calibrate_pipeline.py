"""Calibration (eq. 23) + full RaanA pipeline behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import calibrate as cal
from repro.core import pipeline as pipe
from repro.models import transformer as tf


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get_tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i),
                                             (1, 49), 0, cfg.vocab)}
               for i in range(2)]
    lwc = lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False)
    stats = cal.calibrate(lwc, params, batches)
    return cfg, params, stats


def test_calibration_covers_all_linears(setup):
    cfg, params, stats = setup
    # 4 layers x (wq wk wv wo wi(mlp) wo(mlp)) + lm_head
    assert len(stats) == cfg.n_layers * 6 + 1
    for st in stats.values():
        assert st.alpha > 0
        assert np.isfinite(st.alpha)
        assert st.x_col_sq.shape == (st.d,)
        assert (st.x_col_sq >= 0).all()


def test_zero_shot_tokens_valid():
    toks = cal.zero_shot_tokens(256, 512)
    assert toks.shape == (1, 513)
    assert toks.min() >= 0 and toks.max() < 256


def test_quantize_model_budget_and_quality(setup):
    cfg, params, stats = setup
    test_batch = {"tokens": jax.random.randint(jax.random.PRNGKey(9),
                                               (2, 49), 0, cfg.vocab)}
    base = float(tf.loss_fn(cfg, params, test_batch))
    losses = {}
    for avg in (8.3, 2.3):
        qp, rep = pipe.quantize_model(cfg, params, stats, avg,
                                      jax.random.PRNGKey(1))
        assert rep.avg_bits <= avg + 0.02       # budget respected
        assert rep.avg_bits > avg - 1.0
        losses[avg] = float(tf.loss_fn(cfg, qp, test_batch, scan=False))
    # 8-bit must be near-lossless; at random init 2.3 bits only needs to stay
    # in the same regime (trained-model ordering is covered by test_system)
    assert abs(losses[8.3] - base) < 0.02 * abs(base)
    assert abs(losses[2.3] - base) < 0.2 * abs(base)


def test_quantized_tree_structure(setup):
    cfg, params, stats = setup
    from repro.core.qlinear import QuantizedLinear
    qp, rep = pipe.quantize_model(cfg, params, stats, 4.3,
                                  jax.random.PRNGKey(2))
    assert isinstance(qp["layers"][0], list)
    lp0 = qp["layers"][0][0]
    assert isinstance(lp0["attn"]["wq"], QuantizedLinear)
    # norms untouched
    assert isinstance(lp0["ln1"]["scale"], jax.Array)
    # embed / lm_head untouched
    assert not isinstance(qp["embed"], QuantizedLinear)
    assert not isinstance(qp["lm_head"], QuantizedLinear)
    assert rep.n_layers == len(stats) - 1      # lm_head excluded


def test_uniform_quantization_scannable():
    cfg = registry.get_tiny("mixtral-8x7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(3))
    qp = pipe.quantize_params_uniform(cfg, params, 4, jax.random.PRNGKey(4))
    assert tf.layers_scannable(qp)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 17), 0,
                                          cfg.vocab)}
    l_scan = tf.loss_fn(cfg, qp, batch, scan=True)
    l_unrl = tf.loss_fn(cfg, qp, batch, scan=False)
    np.testing.assert_allclose(l_scan, l_unrl, rtol=2e-4, atol=2e-4)
    assert bool(jnp.isfinite(l_scan))


def test_uniform_quantization_under_eval_shape():
    cfg = registry.get_tiny("deepseek-v2-236b")
    sds = jax.eval_shape(
        lambda: pipe.quantize_params_uniform(
            cfg, tf.init_params(cfg, jax.random.PRNGKey(0)), 4,
            jax.random.PRNGKey(1)))
    leaves = jax.tree.leaves(sds)
    assert all(hasattr(l, "shape") for l in leaves)
    assert any(l.dtype == jnp.uint8 for l in leaves)   # packed codes exist
