"""Documentation front door stays honest: README/DESIGN internal links
must resolve (files and heading anchors), and every `launch/serve.py` CLI
flag must appear in the README's CLI reference.  Runs in tier-1 and as the
CI docs job."""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _slug(heading: str) -> str:
    """GitHub-style heading anchor: lowercase, drop punctuation (keeping
    word chars, hyphens and spaces), spaces -> hyphens."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s, flags=re.ASCII)
    return s.replace(" ", "-")


def _anchors(md_path: Path) -> set:
    out = set()
    in_code = False
    for line in md_path.read_text().splitlines():
        if line.lstrip().startswith("```"):
            in_code = not in_code
        elif not in_code and line.startswith("#"):
            out.add(_slug(line.lstrip("#")))
    return out


def _broken_links(md_path: Path) -> list:
    errors = []
    for target in LINK_RE.findall(md_path.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, anchor = target.partition("#")
        dest = (md_path.parent / path) if path else md_path
        if not dest.exists():
            errors.append(f"{md_path.name}: broken link ({target})")
        elif anchor and dest.suffix == ".md" and anchor not in _anchors(dest):
            errors.append(f"{md_path.name}: missing anchor ({target})")
    return errors


def test_readme_exists_with_required_sections():
    readme = ROOT / "README.md"
    assert readme.exists(), "README.md is the front door — it must exist"
    text = readme.read_text()
    for needle in ("Quickstart", "Architecture map", "CLI reference",
                   "BENCH_serve.json", "DESIGN.md"):
        assert needle in text, f"README.md lacks a {needle!r} section"


def test_readme_links_resolve():
    errors = _broken_links(ROOT / "README.md")
    assert not errors, "\n".join(errors)


def test_design_links_resolve():
    errors = _broken_links(ROOT / "DESIGN.md")
    assert not errors, "\n".join(errors)


def test_design_has_speculative_section():
    anchors = _anchors(ROOT / "DESIGN.md")
    assert any(a.startswith("9-self-speculative") for a in anchors), (
        "DESIGN.md §9 (speculative decoding) missing")


def test_every_serve_cli_flag_documented_in_readme():
    src = (ROOT / "src" / "repro" / "launch" / "serve.py").read_text()
    flags = re.findall(r'add_argument\(\s*"(--[a-z0-9-]+)"', src)
    assert "--speculate" in flags and "--draft-bits" in flags  # regex sanity
    readme = (ROOT / "README.md").read_text()
    missing = [f for f in flags if f not in readme]
    assert not missing, (
        f"launch/serve.py flags missing from the README CLI reference: "
        f"{missing}")
