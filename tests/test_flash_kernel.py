"""Fused flash-attention Pallas kernel vs the jnp oracle (interpret=True),
sweeping GQA ratios, window sizes, ragged lengths and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _qkv(b, s, h, kv, hd, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (b, s, h, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kv,hd", [
    (1, 128, 4, 4, 32),     # MHA
    (2, 96, 4, 2, 16),      # GQA, ragged seq (not block-aligned)
    (1, 256, 8, 1, 32),     # MQA
    (2, 64, 6, 2, 64),      # 3-way groups
])
def test_flash_kernel_causal(b, s, h, kv, hd):
    q, k, v = _qkv(b, s, h, kv, hd, seed=s)
    out = flash_attention_pallas(q, k, v, causal=True, bq=64, bk=64,
                                 interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [16, 50])
def test_flash_kernel_window(window):
    q, k, v = _qkv(1, 160, 4, 2, 32, seed=7)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 bq=64, bk=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_flash_kernel_noncausal():
    q, k, v = _qkv(2, 80, 2, 2, 16, seed=3)
    out = flash_attention_pallas(q, k, v, causal=False, bq=32, bk=32,
                                 interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_flash_kernel_bf16():
    q, k, v = _qkv(1, 128, 4, 2, 32, seed=9, dtype=jnp.bfloat16)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=3e-2, atol=3e-2)


def test_ops_dispatch():
    from repro.kernels.flash_attention import ops
    q, k, v = _qkv(1, 64, 2, 2, 16)
    try:
        ops.set_forced_path("pallas")
        a = ops.attention(q, k, v)
        ops.set_forced_path("ref")
        b = ops.attention(q, k, v)
    finally:
        ops.set_forced_path(None)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
