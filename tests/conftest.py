import jax
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_collection_modifyitems(items):
    """Everything not explicitly tier2 is tier1, so ``-m tier1`` and
    ``-m tier2`` partition the suite exactly (pytest.ini has the tier
    definitions; CI shards them across a job matrix)."""
    for item in items:
        if "tier2" not in item.keywords:
            item.add_marker(pytest.mark.tier1)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
