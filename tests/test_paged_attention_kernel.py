"""Paged-attention kernel parity (DESIGN.md §10).

Property-based harness: the Pallas flash-decode kernel (interpret mode on
CPU, so the *kernel program* itself is what runs) must match the dense
gather reference on randomized pool states — batch size, GQA ratio, block
size, table width, partial final blocks, sliding windows, multi-token
query spans (speculative catch-up/verify), and post-wraparound ring states
— plus engine-level pins: greedy outputs token-identical between the
kernel and gather paths under the mixed-length churn workload on a dense
GQA arch and the sliding-window MoE arch.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from helpers import mixed_requests, noisy, small_pool, tiny

from repro.kernels.paged_attention import ops as pops
from repro.kernels.paged_attention.paged import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.models import attention as attnmod
from repro.models import transformer as tf
from repro.serve import PagedServer

pytestmark = pytest.mark.tier2  # interpret-mode kernel + engine runs


def _pool_state(rng, b, w, kv, g, hd, bs, mb, window, wrapped, dtype):
    """A random but *reachable* pool state: per-request ring capacities are
    whole blocks, block-table rows hold disjoint physical blocks, and pos
    covers pre-fill, partial-final-block, and post-wraparound regimes."""
    h = kv * g
    ring_blocks = rng.integers(1, mb + 1, size=b)
    n_phys = 1 + int(ring_blocks.sum())
    q = rng.normal(size=(b, w, h, hd)).astype(np.float32)
    k_arena = rng.normal(size=(n_phys, bs, kv, hd)).astype(dtype)
    v_arena = rng.normal(size=(n_phys, bs, kv, hd)).astype(dtype)
    bt = np.zeros((b, mb), np.int32)
    nxt = 1
    for i in range(b):
        for j in range(int(ring_blocks[i])):
            bt[i, j] = nxt
            nxt += 1
    ring = (ring_blocks * bs).astype(np.int32)
    pos = np.zeros(b, np.int32)
    for i in range(b):
        cap = int(ring[i])
        hi = 3 * cap if wrapped else cap
        lo = cap + 1 if (wrapped and hi > cap) else w
        pos[i] = rng.integers(max(lo, w), max(hi, w) + 1)
    return (jnp.asarray(q), jnp.asarray(k_arena), jnp.asarray(v_arena),
            jnp.asarray(bt), jnp.asarray(pos), jnp.asarray(ring))


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 3), w=st.sampled_from([1, 2, 4]),
       kv=st.sampled_from([1, 2]), g=st.sampled_from([1, 2, 4]),
       bs=st.sampled_from([4, 8]), mb=st.integers(1, 5),
       window=st.sampled_from([None, 3, 7]),
       wrapped=st.booleans(), seed=st.integers(0, 2**16))
def test_kernel_matches_gather_reference(b, w, kv, g, bs, mb, window,
                                         wrapped, seed):
    rng = np.random.default_rng(seed)
    hd = 8
    q, ka, va, bt, pos, ring = _pool_state(rng, b, w, kv, g, hd, bs, mb,
                                           window, wrapped, np.float32)
    out_k = paged_attention_pallas(q, ka, va, bt, pos, ring, window=window,
                                   interpret=True)
    out_r = paged_attention_ref(q, ka, va, bt, pos, ring, window=window)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), atol=2e-5, rtol=2e-5,
        err_msg=f"b={b} w={w} kv={kv} g={g} bs={bs} mb={mb} "
                f"window={window} wrapped={wrapped} pos={np.asarray(pos)} "
                f"ring={np.asarray(ring)}")


def test_kernel_matches_reference_bf16_arena():
    """bf16 arenas (PoolConfig.kv_dtype) go through the same kernel; the
    comparison is vs the bf16 gather reference at bf16 tolerances."""
    rng = np.random.default_rng(11)
    q, ka, va, bt, pos, ring = _pool_state(rng, 2, 1, 2, 2, 16, 4, 4, None,
                                           True, jnp.bfloat16)
    out_k = paged_attention_pallas(q, ka, va, bt, pos, ring, interpret=True)
    out_r = paged_attention_ref(q, ka, va, bt, pos, ring)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-2, rtol=2e-2)


def test_reference_matches_pre_kernel_decode_math():
    """At W=1 the generalized reference is the original dense-gather decode
    attention — the oracle the engine parity suites were pinned against."""
    rng = np.random.default_rng(3)
    q, ka, va, bt, pos, ring = _pool_state(rng, 3, 1, 2, 2, 8, 4, 4, 5,
                                           True, np.float32)
    got = paged_attention_ref(q, ka, va, bt, pos, ring, window=5)
    # the original inline math, kept verbatim in spirit: gather + softmax
    # over stored>=0 / window validity (no causal term needed at W=1)
    k = attnmod.paged_gather_kv(ka, bt)
    v = attnmod.paged_gather_kv(va, bt)
    b, h, hd = q.shape[0], q.shape[2], q.shape[3]
    length, kv = k.shape[1], k.shape[2]
    g = h // kv
    qf = (q.astype(jnp.float32) * hd ** -0.5).astype(k.dtype)
    s = jnp.einsum("bkgd,bskd->bkgs", qf.reshape(b, kv, g, hd), k,
                   preferred_element_type=jnp.float32)
    stored = attnmod.paged_slot_positions(pos, ring, length)
    valid = (stored >= 0) & (stored > (pos[:, None] - 1) - 5)
    s = jnp.where(valid[:, None, None, :], s, attnmod.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    want = out.reshape(b, 1, h, hd).astype(q.dtype)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_forced_path_dispatch():
    """set_forced_path and the paged_kernel scope drive the dispatcher the
    way the engine and the parity CI leg rely on."""
    assert not pops.kernel_enabled()            # CPU default: gather
    with pops.paged_kernel(True):
        assert pops.kernel_enabled()
        with pops.paged_kernel(False):
            assert not pops.kernel_enabled()
        assert pops.kernel_enabled()
    assert not pops.kernel_enabled()
    pops.set_forced_path("pallas")
    try:
        with pops.paged_kernel(False):
            assert pops.kernel_enabled()        # forced path wins
    finally:
        pops.set_forced_path(None)


# --------------------------------------------------- engine-level parity


@pytest.mark.parametrize("arch", ["llama2-7b", "mixtral-8x7b"])
def test_engine_kernel_vs_gather_greedy_parity(arch):
    """Greedy outputs are token-identical between --paged-kernel and the
    gather path under the mixed-length churn workload (dense GQA and the
    sliding-window ring — the acceptance pin for DESIGN.md §10)."""
    cfg = tiny(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    reqs = mixed_requests(cfg)
    ref = PagedServer(cfg, params, small_pool(), paged_kernel=False).run(
        [dataclasses.replace(r) for r in reqs])
    eng = PagedServer(cfg, params, small_pool(), paged_kernel=True)
    got = eng.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(
            got[r.rid].tokens, ref[r.rid].tokens,
            err_msg=f"{arch}: rid={r.rid}")
    assert eng.decode_trace_count == 1          # kernel path still no-retrace


def test_engine_speculative_kernel_parity():
    """The kernel path's write-then-read verify/catch-up ordering stays
    token-identical on the windowed arch (lookahead reservation keeps the
    up-to-k-past-frontier writes off live history)."""
    cfg = tiny("mixtral-8x7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    reqs = mixed_requests(cfg, n=3)
    ref = PagedServer(cfg, params, small_pool(), paged_kernel=False).run(
        [dataclasses.replace(r) for r in reqs])
    spec = PagedServer(cfg, params, small_pool(), paged_kernel=True,
                       draft_params=noisy(params, 0.005), speculate=3)
    got = spec.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(got[r.rid].tokens, ref[r.rid].tokens,
                                      err_msg=f"rid={r.rid}")
    assert spec.verify_trace_count == 1
