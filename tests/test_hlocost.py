"""The loop-aware HLO cost parser (launch/hlocost.py) — the roofline's
measurement tool — validated against programs with known flop counts."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlocost import analyze_hlo


def _analyze(fn, *args):
    return analyze_hlo(jax.jit(fn).lower(*args).compile().as_text())


def test_single_matmul_exact():
    x = jnp.ones((128, 128))
    r = _analyze(lambda x: x @ x, x)
    assert abs(r["flops"] - 2 * 128 ** 3) / (2 * 128 ** 3) < 1e-6


@pytest.mark.parametrize("k", [1, 3, 9])
def test_scan_trip_counts(k):
    x = jnp.ones((64, 64))
    r = _analyze(
        lambda x: jax.lax.scan(lambda c, _: (c @ c, None), x, None,
                               length=k)[0], x)
    expect = 2 * 64 ** 3 * k
    assert abs(r["flops"] - expect) / expect < 1e-6
    assert r["unknown_trip_whiles"] == 0


def test_grad_of_scan():
    x = jnp.ones((64, 64))

    def loss(x):
        y, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c @ c), None), x, None,
                            length=5)
        return jnp.sum(y)

    r = _analyze(jax.grad(loss), x)
    expect = 2 * 64 ** 3 * 5 * 3          # fwd + 2x bwd matmuls
    assert abs(r["flops"] - expect) / expect < 1e-6


def test_nested_scans_multiply():
    x = jnp.ones((32, 32))

    def nested(x):
        def outer(c, _):
            c2, _ = jax.lax.scan(lambda d, _: (d @ d, None), c, None,
                                 length=3)
            return c2, None
        return jax.lax.scan(outer, x, None, length=4)[0]

    r = _analyze(nested, x)
    expect = 2 * 32 ** 3 * 12
    assert abs(r["flops"] - expect) / expect < 1e-6


def test_bytes_scale_with_trips():
    x = jnp.ones((64, 64))
    r1 = _analyze(lambda x: jax.lax.scan(
        lambda c, _: (c @ c, None), x, None, length=2)[0], x)
    r2 = _analyze(lambda x: jax.lax.scan(
        lambda c, _: (c @ c, None), x, None, length=8)[0], x)
    assert r2["bytes"] > 3 * r1["bytes"]


def test_dryrun_collective_parser_on_text():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ar = bf16[128,256] all-reduce(%x), replica_groups={{0,1},{2,3}}
  %ag.1 = f32[64,64] all-gather(%y), dimensions={0}
  %done = f32[8] all-reduce-done(%st)
"""
    r = collective_bytes(hlo)
    assert r["bytes_by_kind"]["all-reduce"] == 128 * 256 * 2
    assert r["bytes_by_kind"]["all-gather"] == 64 * 64 * 4
    assert r["counts"]["all-reduce"] == 1   # -done not double counted
