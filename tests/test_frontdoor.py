"""Front-door correctness (DESIGN.md §12): drop-and-replay preemption must
not change what a request generates, the scheduler's admission policies
(priority, weighted fair share, share cap, SLO hysteresis) must hold on a
deterministic fake engine, the SSE codec must round-trip, and the HTTP
server must boot, stream, and shut down cleanly as a subprocess."""
import collections
import dataclasses
import os
import signal
import subprocess
import sys
import threading
import time
import types
from pathlib import Path

import numpy as np
import pytest
from helpers import mixed_requests, small_pool, tiny

from repro.serve import Request
from repro.serve.frontdoor import SchedConfig, Scheduler
from repro.serve.frontdoor.sse import encode_event, iter_events

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------- SSE codec


def test_sse_round_trip():
    frames = [("token", {"rid": 0, "token": 7, "text": "a"}),
              ("token", {"rid": 0, "token": 9, "text": "\n"}),
              ("done", {"rid": 0, "tokens": [7, 9], "n_tokens": 2})]
    wire = b"".join(encode_event(e, d) for e, d in frames).decode()
    parsed = list(iter_events(wire.splitlines(keepends=True)))
    assert parsed == frames


def test_sse_parser_skips_comments_and_unterminated_tail():
    lines = [": keep-alive\n", "event: token\n", 'data: {"x": 1}\n', "\n",
             "event: token\n", 'data: {"never": "terminated"}\n']
    assert list(iter_events(lines)) == [("token", {"x": 1})]


def test_sse_multi_data_lines_join():
    lines = ["event: blob\n", "data: [1,\n", "data: 2]\n", "\n"]
    assert list(iter_events(lines)) == [("blob", [1, 2])]


# ------------------------------------------------------- scheduler policies


class FakePool:
    def __init__(self, max_slots):
        self.max_slots = max_slots


class FakeEngine:
    """Deterministic engine stub exposing exactly the surface Scheduler
    consumes: every admitted request 'decodes' one token per step and
    finishes after ``gen`` steps."""

    def __init__(self, max_slots=4, gen=100):
        self.pool = FakePool(max_slots)
        self.decode_gaps = collections.deque(maxlen=2048)
        self.gen = gen
        self.running = {}           # rid -> [req, done, t_admit]
        self.order = []             # admission order
        self.preempted = []
        self._t = 0.0

    def now(self):
        return self._t

    def validate(self, req):
        pass

    def can_admit(self, req):
        return len(self.running) < self.pool.max_slots

    def submit(self, req):
        self.running[req.rid] = [req, 0, self._t]
        self.order.append(req.rid)

    def poll(self):
        return bool(self.running)

    @property
    def active_count(self):
        return len(self.running)

    def inflight(self):
        return [(v[0], "decode", v[1], v[2]) for v in self.running.values()]

    def preempt(self, rid):
        if rid not in self.running:
            return None
        self.preempted.append(rid)
        return self.running.pop(rid)[0]

    def cancel(self, rid):
        return self.running.pop(rid, None) is not None

    def step(self, prefill=True):
        self._t += 1.0
        finished = {}
        for rid in list(self.running):
            self.running[rid][1] += 1
            if self.running[rid][1] >= self.gen:
                req = self.running.pop(rid)[0]
                finished[rid] = types.SimpleNamespace(
                    rid=rid, tenant=req.tenant)
        return finished


def _req(rid, tenant="default", priority=0):
    return Request(rid=rid, prompt=np.zeros(4, np.int32), max_new=4,
                   tenant=tenant, priority=priority)


def test_priority_admitted_before_fifo():
    eng = FakeEngine(max_slots=1)
    sched = Scheduler(eng)
    sched.submit(_req(0, priority=0))
    sched.submit(_req(1, priority=5))      # later submit, higher priority
    sched.tick()
    assert eng.order[0] == 1


def test_weighted_fair_share_split():
    eng = FakeEngine(max_slots=3)
    sched = Scheduler(eng)
    for i in range(4):
        sched.submit(_req(i, tenant="heavy"), weight=2.0)
    for i in range(4, 8):
        sched.submit(_req(i, tenant="light"), weight=1.0)
    sched.tick()
    held = collections.Counter(r.tenant for r, *_ in eng.inflight())
    assert held == {"heavy": 2, "light": 1}


def test_share_cap_binds_only_while_others_wait():
    # alone, a tenant may take every slot despite the cap...
    eng = FakeEngine(max_slots=4)
    sched = Scheduler(eng, SchedConfig(max_tenant_share=0.5))
    for i in range(4):
        sched.submit(_req(i, tenant="solo"))
    sched.tick()
    assert eng.active_count == 4
    # ...but with another tenant waiting, it is capped at ceil(0.5*4)=2
    eng = FakeEngine(max_slots=4)
    sched = Scheduler(eng, SchedConfig(max_tenant_share=0.5))
    for i in range(4):
        sched.submit(_req(i, tenant="greedy"))
    for i in range(4, 6):
        sched.submit(_req(i, tenant="other"))
    sched.tick()
    held = collections.Counter(r.tenant for r, *_ in eng.inflight())
    assert held["greedy"] == 2 and held["other"] == 2


def test_preempts_lower_priority_victim_and_requeues():
    eng = FakeEngine(max_slots=2)
    sched = Scheduler(eng)
    sched.submit(_req(0, priority=0))
    sched.submit(_req(1, priority=0))
    sched.tick()                           # pool full of priority-0 work
    sched.submit(_req(2, priority=5))
    sched.tick()                           # evicts one victim, requeues it
    assert sched.stats["preempted"] == 1
    assert len(eng.preempted) == 1
    sched.tick()                           # freed slot goes to the waiter
    assert 2 in eng.running
    # the victim is queued again, not lost
    assert sched.queued() + eng.active_count == 3


def test_no_preemption_when_disabled():
    eng = FakeEngine(max_slots=1)
    sched = Scheduler(eng, SchedConfig(preemption=False))
    sched.submit(_req(0, priority=0))
    sched.tick()
    sched.submit(_req(1, priority=5))
    sched.tick()
    assert eng.preempted == [] and sched.stats["preempted"] == 0


def test_slo_throttle_hysteresis():
    eng = FakeEngine(max_slots=2)
    sched = Scheduler(eng, SchedConfig(slo_p95_ms=10.0, slo_min_samples=4,
                                       slo_window=8, slo_resume_frac=0.5))
    sched.submit(_req(0))
    sched.tick()                                   # one active decoder
    assert sched.allow_prefill()                   # below min samples
    eng.decode_gaps.extend([0.020] * 8)            # p95 = 20ms > 10ms
    sched._update_slo()
    assert sched.throttled and not sched.allow_prefill()
    assert sched.stats["slo_throttle_on"] == 1
    eng.decode_gaps.extend([0.008] * 8)            # 8ms: below target but
    sched._update_slo()                            # above 0.5*10 = 5ms
    assert sched.throttled                         # hysteresis holds
    eng.decode_gaps.extend([0.004] * 8)            # 4ms < 5ms: resume
    sched._update_slo()
    assert not sched.throttled and sched.allow_prefill()
    assert sched.stats["slo_throttle_off"] == 1


def test_throttled_prefill_still_runs_when_pool_idle():
    eng = FakeEngine(max_slots=2)
    sched = Scheduler(eng, SchedConfig(slo_p95_ms=10.0, slo_min_samples=4))
    eng.decode_gaps.extend([0.020] * 8)
    sched._update_slo()
    assert sched.throttled
    assert eng.active_count == 0 and sched.allow_prefill()


def test_cancel_queued_and_running():
    eng = FakeEngine(max_slots=1)
    sched = Scheduler(eng)
    sched.submit(_req(0))
    sched.submit(_req(1))
    sched.tick()                           # 0 running, 1 queued
    assert sched.cancel(1) and sched.queued() == 0
    assert sched.cancel(0) and eng.active_count == 0
    assert not sched.cancel(99)


# --------------------------------------------- preemption parity (tier 2)


@pytest.mark.tier2
@pytest.mark.parametrize("arch", ["llama2-7b", "mixtral-8x7b"])
def test_preempt_replay_greedy_parity(arch):
    """A request preempted mid-decode and replayed must emit exactly the
    tokens of an uninterrupted run — on the cacheable dense arch (warm
    replay through the prefix cache) and the windowed MoE arch (cache
    bypassed, cold re-prefill of the served sequence)."""
    import jax
    from repro.models import transformer as tf
    from repro.serve import PagedServer

    cfg = tiny(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    ref = PagedServer(cfg, params, small_pool()).run(mixed_requests(cfg))

    engine = PagedServer(cfg, params, small_pool())
    engine.start_clock()
    for r in mixed_requests(cfg):
        engine.submit(r)
    results, preempted = {}, False
    for _ in range(10_000):
        if not engine.poll():
            break
        results.update(engine.step())
        if not preempted:
            for req, phase, done, _t in engine.inflight():
                if req.rid == 0 and phase == "decode" and done >= 3:
                    victim = engine.preempt(0)
                    assert victim is not None
                    engine.submit(victim)
                    preempted = True
                    break
    assert preempted, "request 0 finished before it could be preempted"
    assert engine.stats["preemptions"] == 1
    assert results[0].preemptions == 1
    for rid, res in ref.items():
        np.testing.assert_array_equal(
            results[rid].tokens, res.tokens,
            err_msg=f"{arch}: rid={rid} diverged after preempt+replay")
    for res in results.values():
        assert res.ttft_s > 0.0
        assert len(res.token_times) == len(res.tokens)
        assert np.all(np.diff(res.token_times) >= 0)


@pytest.mark.tier2
def test_scheduler_end_to_end_on_real_engine():
    """Scheduler.tick over a real tiny engine: everything completes, and
    outputs match plain engine.run (admission order cannot change greedy
    tokens)."""
    import jax
    from repro.models import transformer as tf
    from repro.serve import PagedServer

    cfg = tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    ref = PagedServer(cfg, params, small_pool()).run(mixed_requests(cfg))

    engine = PagedServer(cfg, params, small_pool())
    engine.start_clock()
    sched = Scheduler(engine, SchedConfig(slo_p95_ms=1e6))
    for i, r in enumerate(mixed_requests(cfg)):
        sched.submit(dataclasses.replace(r, tenant=f"t{i % 2}",
                                         priority=i % 3))
    results = {}
    for _ in range(10_000):
        if not sched.has_work():
            break
        results.update(sched.tick())
    assert set(results) == set(ref)
    for rid, res in ref.items():
        np.testing.assert_array_equal(results[rid].tokens, res.tokens)


# ------------------------------------------------------ HTTP smoke (tier 2)


@pytest.mark.tier2
def test_http_smoke_stream_and_clean_shutdown():
    """Boot the front door as a subprocess, stream one generation over SSE
    via the bundled client, hit /healthz, then SIGTERM and require a clean
    exit — the same probe CI's serve-smoke leg runs."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "llama2-7b",
         "--tiny", "--serve", "--port", "0", "--slots", "2",
         "--prompt-len", "32", "--gen", "16"],
        cwd=REPO, env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    lines = []
    reader = threading.Thread(
        target=lambda: lines.extend(iter(proc.stdout.readline, "")),
        daemon=True)
    reader.start()
    try:
        port = None
        deadline = time.monotonic() + 300
        while time.monotonic() < deadline and port is None:
            for line in list(lines):
                if "frontdoor listening on" in line:
                    port = int(line.rsplit(":", 1)[1])
                    break
            assert proc.poll() is None, "server died:\n" + "".join(lines)
            time.sleep(0.5)
        assert port is not None, "no listening line:\n" + "".join(lines)

        from repro.serve.frontdoor.client import stream_generate
        events = list(stream_generate("127.0.0.1", port,
                                      prompt="the quick brown fox",
                                      max_new=8, timeout=120.0))
        tokens = [d for e, d in events if e == "token"]
        dones = [d for e, d in events if e == "done"]
        assert len(tokens) >= 1, events
        assert len(dones) == 1 and dones[0]["n_tokens"] == len(tokens)
        assert dones[0]["tokens"] == [t["token"] for t in tokens]

        import http.client
        conn = http.client.HTTPConnection("127.0.0.1", port, timeout=30)
        conn.request("GET", "/healthz")
        assert conn.getresponse().status == 200
        conn.close()
    finally:
        if proc.poll() is None:
            proc.send_signal(signal.SIGTERM)
        rc = proc.wait(timeout=30)
        reader.join(timeout=5)
    assert rc == 0, f"unclean exit {rc}:\n" + "".join(lines)
    assert any("shut down cleanly" in line for line in lines)
