"""Shared tiny-model / workload builders for the serving test suites.

One definition of the mixed-length churn workload, the tiny-arch factory,
and the small paged pool, imported by test_paged_engine.py,
test_prefix_cache.py, test_speculative.py and
test_paged_attention_kernel.py (tests/ is on sys.path via pytest rootdir
insertion, like _hypothesis_compat).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import transformer as tf
from repro.serve import PoolConfig, Request

# Mixed prompt/gen lengths; fewer slots than requests so completions must
# free capacity for queued requests to join mid-flight.
PROMPT_LENS = [5, 9, 16, 3, 11]
GEN_LENS = [12, 4, 9, 7, 5]


def nodrop(cfg):
    """Routing must be batch-composition independent for token parity."""
    if cfg.moe is not None:
        return cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                 capacity_factor=64.0))
    return cfg


def tiny(arch):
    return nodrop(registry.get_tiny(arch))


def tiny_model(arch):
    """(cfg, params) for a tiny no-drop variant of ``arch``."""
    cfg = tiny(arch)
    return cfg, tf.init_params(cfg, jax.random.PRNGKey(0))


def small_pool(**kw) -> PoolConfig:
    """The small paged pool every engine test runs against (tight enough
    that block tables churn, chunked prefill interleaves, and rings wrap)."""
    kw.setdefault("max_slots", 2)
    kw.setdefault("block_size", 4)
    kw.setdefault("max_context", 32)
    kw.setdefault("prefill_chunk", 4)
    return PoolConfig(**kw)


def mixed_requests(cfg, n: int = len(PROMPT_LENS), seed: int = 0):
    """The mixed-length churn workload (PROMPT_LENS x GEN_LENS)."""
    reqs = []
    for i, (pl, gl) in enumerate(list(zip(PROMPT_LENS, GEN_LENS))[:n]):
        prompt = np.asarray(jax.random.randint(
            jax.random.PRNGKey(seed * 100 + i), (pl,), 0, cfg.vocab),
            np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=gl))
    return reqs


def shared_prefix_requests(cfg, n=4, sys_len=12, tail=4, gen=6, seed=3):
    """n requests sharing a system prompt, each with a distinct tail."""
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(0, cfg.vocab, sys_len).astype(np.int32)
    return [Request(rid=i,
                    prompt=np.concatenate(
                        [sys_p,
                         rng.integers(0, cfg.vocab, tail).astype(np.int32)]),
                    max_new=gen)
            for i in range(n)]


def noisy(params, scale, seed=42):
    """An imperfect draft: the same weights plus gaussian noise — enough
    model mismatch to produce genuinely mixed accept/reject rounds."""
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    out = [l + scale * jax.random.normal(k, l.shape, l.dtype)
           if jnp.issubdtype(l.dtype, jnp.floating) else l
           for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)
