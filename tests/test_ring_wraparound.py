"""Ring-buffer wraparound coverage: cache_insert at pos >= cap, prefill
filling past the capacity (the _ring_fill tail branch), decode parity with
the windowed full forward across the wrap boundary, and paged-vs-dense ring
attention equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import attention as attnmod
from repro.models import decode as dec
from repro.models import transformer as tf
from repro.models.attention import KVCache, cache_insert, decode_attention


def test_cache_insert_wraps_to_slot_pos_mod_cap():
    """Inserting positions 0..9 into a cap-4 ring leaves exactly the last 4
    positions, each at slot pos % cap."""
    cap, kv, hd = 4, 2, 8
    cache = KVCache.init(1, cap, kv, hd)
    for pos in range(10):
        k = jnp.full((1, 1, kv, hd), float(pos))
        v = jnp.full((1, 1, kv, hd), float(100 + pos))
        cache = cache_insert(cache, k, v, jnp.int32(pos))
    for pos in range(6, 10):                      # the surviving tail
        slot = pos % cap
        assert float(cache.k[0, slot, 0, 0]) == float(pos)
        assert float(cache.v[0, slot, 0, 0]) == float(100 + pos)


def test_windowed_decode_parity_across_wrap():
    """Ring decode must track the windowed full forward before, at, and well
    past the wrap boundary (prompt < window, generation crosses it twice)."""
    cfg = registry.get_tiny("llama2-7b").with_(window=6)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    b, s_pre, s_tot = 1, 3, 18                    # cap = 6; wraps at pos 6, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s_tot), 0, cfg.vocab)
    logits_full, _ = tf.forward(cfg, params, toks, scan=False)
    lg, caches, _ = dec.prefill(cfg, params, toks[:, :s_pre], context=s_tot,
                                scan=True)
    errs = []
    for t in range(s_pre, s_tot):
        sl, caches = dec.decode_step(cfg, params, caches, toks[:, t:t + 1],
                                     jnp.int32(t), scan=True)
        errs.append(float(jnp.abs(sl - logits_full[:, t]).max()))
    assert max(errs) < 2e-4, errs


def test_ring_fill_long_prompt_then_decode():
    """Prompt longer than the window exercises the _ring_fill tail branch
    (only the last cap tokens are kept, at slots t % cap); decode continuing
    from it must match the windowed full forward."""
    cfg = registry.get_tiny("llama2-7b").with_(window=5)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    b, s_pre, s_tot = 1, 9, 14                    # prompt 9 > cap 5
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s_tot), 0, cfg.vocab)
    logits_full, _ = tf.forward(cfg, params, toks, scan=False)
    lg, caches, _ = dec.prefill(cfg, params, toks[:, :s_pre], context=s_tot,
                                scan=True)
    assert float(jnp.abs(lg[:, -1] - logits_full[:, s_pre - 1]).max()) < 2e-4
    errs = []
    for t in range(s_pre, s_tot):
        sl, caches = dec.decode_step(cfg, params, caches, toks[:, t:t + 1],
                                     jnp.int32(t), scan=True)
        errs.append(float(jnp.abs(sl - logits_full[:, t]).max()))
    assert max(errs) < 2e-4, errs


def test_paged_ring_matches_dense_ring_attention():
    """paged_decode_attention over a block-ring (capacity rounded up to a
    block multiple, exact window masking) == decode_attention over a dense
    ring of capacity == window, across the wrap boundary."""
    key = jax.random.PRNGKey(3)
    b, kv, h, hd, window, bs = 1, 2, 4, 8, 6, 4
    ring_blocks = -(-window // bs)                # 2 blocks -> ring cap 8
    ring_cap = ring_blocks * bs
    n_blocks = 1 + ring_blocks                    # + null block
    k_arena = jnp.zeros((n_blocks, bs, kv, hd))
    v_arena = jnp.zeros((n_blocks, bs, kv, hd))
    bt = jnp.asarray([[1, 2]], jnp.int32)
    dense = KVCache.init(b, window, kv, hd)
    for pos in range(15):                         # wraps both rings
        kk = jax.random.normal(jax.random.fold_in(key, 2 * pos),
                               (b, 1, kv, hd))
        vv = jax.random.normal(jax.random.fold_in(key, 2 * pos + 1),
                               (b, 1, kv, hd))
        q = jax.random.normal(jax.random.fold_in(key, 1000 + pos),
                              (b, 1, h, hd))
        dense = cache_insert(dense, kk, vv, jnp.int32(pos))
        pb, off = attnmod.paged_write_indices(
            jnp.asarray([pos], jnp.int32), jnp.asarray([ring_cap], jnp.int32),
            bt, bs, jnp.asarray([True]))
        k_arena = k_arena.at[pb, off].set(kk[:, 0])
        v_arena = v_arena.at[pb, off].set(vv[:, 0])
        ref = decode_attention(q, dense, jnp.int32(pos + 1))
        got = attnmod.paged_decode_attention(
            q, k_arena, v_arena, bt, jnp.asarray([pos + 1], jnp.int32),
            jnp.asarray([ring_cap], jnp.int32), window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"pos={pos}")


def test_paged_full_context_matches_dense_cache():
    """Without a window, a never-wrapping block table reproduces the dense
    full-context cache attention exactly."""
    key = jax.random.PRNGKey(4)
    b, kv, h, hd, bs, cap = 1, 2, 2, 8, 4, 12
    nb = cap // bs
    k_arena = jnp.zeros((1 + nb, bs, kv, hd))
    v_arena = jnp.zeros((1 + nb, bs, kv, hd))
    bt = jnp.asarray([[1, 2, 3]], jnp.int32)
    dense = KVCache.init(b, cap, kv, hd)
    for pos in range(cap):
        kk = jax.random.normal(jax.random.fold_in(key, 2 * pos), (b, 1, kv, hd))
        vv = jax.random.normal(jax.random.fold_in(key, 2 * pos + 1),
                               (b, 1, kv, hd))
        q = jax.random.normal(jax.random.fold_in(key, 500 + pos), (b, 1, h, hd))
        dense = cache_insert(dense, kk, vv, jnp.int32(pos))
        pb, off = attnmod.paged_write_indices(
            jnp.asarray([pos], jnp.int32), jnp.asarray([cap], jnp.int32),
            bt, bs, jnp.asarray([True]))
        k_arena = k_arena.at[pb, off].set(kk[:, 0])
        v_arena = v_arena.at[pb, off].set(vv[:, 0])
        ref = decode_attention(q, dense, jnp.int32(pos + 1))
        got = attnmod.paged_decode_attention(
            q, k_arena, v_arena, bt, jnp.asarray([pos + 1], jnp.int32),
            jnp.asarray([cap], jnp.int32), window=None)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5, err_msg=f"pos={pos}")
