"""Serving-path correctness: prefill + step-by-step decode must reproduce the
full teacher-forced forward for every architecture (MoE archs with no-drop
capacity so routing is batch-size independent)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import decode as dec
from repro.models import transformer as tf

ARCHS = list(registry.ARCH_IDS)


def _nodrop(cfg):
    if cfg.moe is not None:
        return cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                 capacity_factor=64.0))
    return cfg


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = _nodrop(registry.get_tiny(arch))
    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)
    b, s_pre, s_tot = 2, 8, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s_tot), 0, cfg.vocab)
    kw = {}
    if cfg.pos == "mrope":
        kw["positions"] = jnp.broadcast_to(
            jnp.arange(s_tot, dtype=jnp.int32)[None, None], (3, b, s_tot))
    fkw = {}
    if cfg.enc_dec:
        fkw["enc_embeds"] = jax.random.normal(
            key, (b, cfg.n_audio_ctx, cfg.d_model))
    logits_full, _ = tf.forward(cfg, params, toks,
                                positions=kw.get("positions"),
                                scan=False, **fkw)
    pk = ({"positions": kw["positions"][..., :s_pre]}
          if cfg.pos == "mrope" else {})
    lg, caches, _ = dec.prefill(cfg, params, toks[:, :s_pre],
                                context=s_tot, scan=True, **fkw, **pk)
    errs = [float(jnp.abs(lg[:, -1] - logits_full[:, s_pre - 1]).max())]
    for t in range(s_pre, s_tot):
        sl, caches = dec.decode_step(cfg, params, caches, toks[:, t:t + 1],
                                     jnp.int32(t), scan=True)
        errs.append(float(jnp.abs(sl - logits_full[:, t]).max()))
    assert max(errs) < 2e-4, errs


def test_ring_cache_sliding_window():
    """With window < context, decode must match a full forward whose
    attention is windowed (mixtral-style SWA)."""
    cfg = _nodrop(registry.get_tiny("mixtral-8x7b")).with_(window=6)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    b, s_tot = 1, 14
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s_tot), 0, cfg.vocab)
    logits_full, _ = tf.forward(cfg, params, toks, scan=False)
    lg, caches, _ = dec.prefill(cfg, params, toks[:, :4], context=s_tot,
                                scan=True)
    errs = []
    for t in range(4, s_tot):
        sl, caches = dec.decode_step(cfg, params, caches, toks[:, t:t + 1],
                                     jnp.int32(t), scan=True)
        errs.append(float(jnp.abs(sl - logits_full[:, t]).max()))
    assert max(errs) < 2e-4, errs


def test_decode_quantized_model_runs():
    cfg = registry.get_tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    from repro.core import calibrate as cal
    from repro.core import pipeline as pipe
    toks = cal.zero_shot_tokens(cfg.vocab, 64)
    stats = cal.calibrate(
        lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
        params, [{"tokens": jnp.asarray(toks)}])
    qp, _ = pipe.quantize_model(cfg, params, stats, 4.3, jax.random.PRNGKey(3))
    b = 2
    prompts = jax.random.randint(jax.random.PRNGKey(4), (b, 6), 0, cfg.vocab)
    lg, caches, _ = dec.prefill(cfg, qp, prompts, context=10, scan=False)
    for t in range(6, 10):
        tok = jnp.argmax(lg, axis=-1)[:, None] if lg.ndim == 2 else \
            jnp.argmax(lg[:, -1], axis=-1)[:, None]
        lg, caches = dec.decode_step(cfg, qp, caches, tok, jnp.int32(t),
                                     scan=False)
    assert bool(jnp.isfinite(lg).all())
