"""Baseline PTQ methods (RTN / GPTQ / AWQ-lite) sanity + ordering."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import awq_quantize, gptq_quantize, rtn_quantize


def _weights(d=256, c=64, seed=0):
    return np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (d, c)))


def test_rtn_roundtrip_8bit_near_exact():
    w = _weights()
    wq, _ = rtn_quantize(w, 8, group=64)
    assert np.linalg.norm(wq - w) / np.linalg.norm(w) < 0.01


def test_rtn_more_bits_better():
    w = _weights()
    errs = [np.linalg.norm(rtn_quantize(w, b, 64)[0] - w) for b in (2, 4, 8)]
    assert errs == sorted(errs, reverse=True)


def test_gptq_beats_rtn_on_correlated_inputs():
    """GPTQ exploits input covariance: on correlated X it should beat RTN in
    the ||X(W - What)|| metric it optimizes."""
    d, c, n = 128, 32, 512
    rng = np.random.default_rng(0)
    base = rng.normal(size=(n, 8))
    x = base @ rng.normal(size=(8, d)) + 0.1 * rng.normal(size=(n, d))
    w = _weights(d, c)
    h = x.T @ x
    w_gptq, _ = gptq_quantize(w, h, 3, group=128)
    w_rtn, _ = rtn_quantize(w, 3, group=128)
    e_gptq = np.linalg.norm(x @ (w - w_gptq))
    e_rtn = np.linalg.norm(x @ (w - w_rtn))
    assert e_gptq < e_rtn


def test_awq_scales_salient_dims():
    d, c = 128, 32
    w = _weights(d, c)
    norms = np.ones(d)
    norms[:4] = 50.0
    x = np.array(jax.random.normal(jax.random.PRNGKey(1), (64, d)))
    x[:, :4] *= 50.0
    wq_awq, _, alpha = awq_quantize(w, norms, 2)
    wq_rtn, _ = rtn_quantize(w, 2)
    e_awq = np.linalg.norm(x @ (w - wq_awq))
    e_rtn = np.linalg.norm(x @ (w - wq_rtn))
    assert e_awq < e_rtn
    assert alpha > 0


def test_apply_baseline_to_model():
    from repro.baselines.apply import apply_baseline, collect_hessians
    from repro.configs import registry
    from repro.models import transformer as tf
    cfg = registry.get_tiny("llama2-7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 33), 0,
                                          cfg.vocab)}
    hess, norms = collect_hessians(cfg, params, [batch])
    base = float(tf.loss_fn(cfg, params, batch))
    for method in ("rtn", "gptq", "awq"):
        qp, avg_bits, _ = apply_baseline(cfg, params, method, 8,
                                         hessians=hess, x_col_norms=norms)
        lq = float(tf.loss_fn(cfg, qp, batch, scan=False))
        assert abs(lq - base) < 0.05, (method, lq, base)
        assert 8.0 <= avg_bits < 8.6
