"""Fault-tolerant checkpointing.

Design (numpy .npz per step, no external deps):
  * atomic: write to <dir>/tmp.<step>.<pid>, fsync, rename — a crash mid-write
    can never corrupt the latest checkpoint;
  * keep-N GC with a protected "milestone" stride;
  * resume: ``latest_step()`` scans the directory, ``restore`` rebuilds the
    pytree from the saved treedef;
  * **elastic re-mesh**: arrays are saved as host (fully-replicated) numpy, so
    ``restore(..., sharding_fn)`` can place them onto ANY mesh — changing pod
    count / mesh shape between runs re-shards transparently (tested in
    tests/test_checkpoint.py);
  * async mode: the serialize+write happens on a background thread, with a
    barrier before the next save (overlap checkpoint I/O with compute).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    keys = [f"leaf_{i}" for i in range(len(leaves))]
    return list(zip(keys, leaves)), treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, milestone_every: int = 0,
                 async_write: bool = False):
        self.dir = directory
        self.keep = keep
        self.milestone_every = milestone_every
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- paths
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:010d}.npz")

    def all_steps(self) -> list[int]:
        steps = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".npz"):
                try:
                    steps.append(int(f[5:-4]))
                except ValueError:
                    pass
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -------------------------------------------------------------- save
    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        """Serialize pytree (device -> host) and write atomically."""
        self.wait()
        named, treedef = _flatten_with_paths(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in named}
        meta = {"step": step, "treedef": str(treedef),
                "extra": extra or {}, "time": time.time()}

        def _write():
            tmp = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}")
            with open(tmp, "wb") as f:
                np.savez(f, __meta__=json.dumps(meta), **host)
                f.flush()
                os.fsync(f.fileno())
            os.rename(tmp, self._path(step))
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def _gc(self) -> None:
        steps = self.all_steps()
        protected = set(steps[-self.keep:])
        if self.milestone_every:
            protected |= {s for s in steps if s % self.milestone_every == 0}
        for s in steps:
            if s not in protected:
                try:
                    os.remove(self._path(s))
                except OSError:
                    pass

    # ------------------------------------------------------------ restore
    def restore(self, step: int, like: Any,
                sharding_fn: Optional[Callable[[Any], Any]] = None
                ) -> tuple[Any, dict]:
        """Rebuild the pytree of ``like``'s structure from checkpoint ``step``.

        ``sharding_fn(leaf_host_array, like_leaf) -> placed array`` lets the
        caller place each leaf on an arbitrary mesh (elastic re-mesh).
        """
        with np.load(self._path(step), allow_pickle=False) as z:
            meta = json.loads(str(z["__meta__"]))
            leaves_like, treedef = jax.tree.flatten(like)
            out = []
            for i, ll in enumerate(leaves_like):
                arr = z[f"leaf_{i}"]
                if sharding_fn is not None:
                    out.append(sharding_fn(arr, ll))
                else:
                    out.append(jax.numpy.asarray(arr))
            return jax.tree.unflatten(treedef, out), meta["extra"]
