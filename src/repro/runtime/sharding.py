"""GSPMD sharding rules (DESIGN.md §5).

Axes: (pod?, data, model).  Batch shards over all data-parallel axes
("pod"+"data"); weights shard FSDP(ZeRO-3)-style over "data" and
tensor-parallel over "model" for training, model-only for serving (no
per-token all-gathers); MoE experts shard over "model" when divisible
(expert parallelism), falling back to intra-expert TP otherwise; KV caches
shard batch over dp and sequence over "model" (flash-decoding style partial
softmax combine is then inserted by XLA).

Every rule passes through ``_fit`` which drops any axis that does not divide
the dimension — rules degrade to replication rather than failing, so tiny
smoke configs and odd head counts (yi's 56 heads) stay valid.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _fit(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec entries that don't divide the corresponding dim."""
    out = []
    for i, dim in enumerate(shape):
        ax = spec[i] if i < len(spec) else None
        if ax is not None and dim % _axis_size(mesh, ax) != 0:
            ax = None
        out.append(ax)
    return P(*out)


def _leaf_key(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def _in_moe(path) -> bool:
    return any(getattr(e, "key", None) == "moe" for e in path)


# weight keys whose 2-D layout is (d_in, d_out) -> FSDP d_in, TP d_out
_COL_KEYS = {"wq", "wk", "wv", "wi", "swi", "wg", "wr", "ck", "cr",
             "wq_a", "wq_b", "wkv_a", "wkv_b", "embed"}
# (d_hidden, d_model) down-projections -> TP d_in, FSDP d_out
_ROW_KEYS = {"wo", "swo", "cv", "lm_head"}


# serve-mode EP weights also shard their inner dim over "data" when the
# per-chip residue after model-axis EP exceeds this (deepseek-v2's 450 GB of
# experts do not fit 16 chips; mixtral's 94 GB do) — §Perf iteration D1.
SERVE_EP_INNER_SHARD_LIMIT = 8 * 2 ** 30


def _param_rule(key: str, shape, mesh: Mesh, path, serve: bool) -> P:
    dp = dp_axes(mesh)
    fsdp = None if serve else "data"
    nd = len(shape)
    if _in_moe(path) and key in ("wi", "wo") and nd in (3, 4):
        # stacked (L, E, a, b) or unstacked (E, a, b) expert weights
        lead = (None,) * (nd - 3)
        e = shape[nd - 3]
        if e % mesh.shape["model"] == 0:
            # expert parallelism on model; shard inner dim over data (ZeRO /
            # fit) when training or when the EP residue still breaks HBM
            per_chip = 2 * np.prod(shape) / mesh.shape["model"]
            inner = ("data" if not serve
                     or per_chip > SERVE_EP_INNER_SHARD_LIMIT else None)
            return _fit(P(*lead, "model", None, inner), shape, mesh)
        return _fit(P(*lead, None, "data" if not serve else None, "model"),
                    shape, mesh)
    if key == "router":
        return P(*([None] * nd))
    if key in _COL_KEYS and nd >= 2:
        lead = (None,) * (nd - 2)
        return _fit(P(*lead, fsdp, "model"), shape, mesh)
    if key in _ROW_KEYS and nd >= 2:
        lead = (None,) * (nd - 2)
        return _fit(P(*lead, "model", fsdp), shape, mesh)
    return P(*([None] * nd))  # norms, biases, LoRAs, convs: replicated


def param_specs(params: Any, mesh: Mesh, serve: bool = False):
    """PartitionSpec tree for a (possibly quantized) param tree."""

    def rule(path, leaf):
        key = _leaf_key(path)
        shape = tuple(leaf.shape)
        # quantized leaves: shard packed codes / rescale like the weight
        names = [str(getattr(e, "key", getattr(e, "name", ""))) for e in path]
        if "packed" in names or "rescale" in names:
            # find the owning weight's key (the dict key above the dataclass)
            wkey = ""
            for n in names:
                if n in _COL_KEYS | _ROW_KEYS | {"wi", "wo"}:
                    wkey = n
            nd = len(shape)
            if nd >= 2:
                lead = (None,) * (nd - 2)
                if "rescale" in names[-1:]:
                    return _fit(P(*((None,) * (nd - 1)), "model"), shape, mesh)
                return _fit(P(*lead, None, "model"), shape, mesh)
            return P(*([None] * nd))
        if any(n in ("signs1", "signs2", "mean_col", "w_out", "out_idx",
                     "keep_idx") for n in names):
            return P(*([None] * len(shape)))
        return _param_rule(key, shape, mesh, path, serve)

    return jax.tree_util.tree_map_with_path(rule, params)


def batch_specs(batch: Any, mesh: Mesh):
    dp = dp_axes(mesh)

    def rule(path, leaf):
        key = _leaf_key(path)
        shape = tuple(leaf.shape)
        if key == "positions":               # (3, B, S)
            return _fit(P(None, dp, None), shape, mesh)
        if key == "pos" or len(shape) == 0:
            return P()
        return _fit(P(dp, *([None] * (len(shape) - 1))), shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, batch)


def cache_specs(caches: Any, mesh: Mesh):
    """(n_j, B, S?, ...) cache leaves: batch over dp, dim-2 over model."""
    dp = dp_axes(mesh)

    def rule(path, leaf):
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd <= 1:
            return P(*([None] * nd))
        spec = [None, dp] + [None] * (nd - 2)
        if nd >= 4:
            spec[2] = "model"                 # sequence / capacity axis
        return _fit(P(*spec), shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, caches)


def replicate_specs(tree: Any):
    return jax.tree.map(lambda l: P(*([None] * getattr(l, "ndim", 0))), tree)


def named(tree_specs: Any, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
