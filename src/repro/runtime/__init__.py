"""Distributed runtime: sharding rules, step builders, microbatching,
gradient compression, fault tolerance."""
