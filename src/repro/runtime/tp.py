"""Tensor-parallel serving over ``shard_map`` (DESIGN.md §11).

One engine drives every chip of a ``("data", "model")`` mesh: the paged
serving steps run inside an explicit ``shard_map`` whose in/out specs are
built here.  The partitioning scheme is chosen for the *quantized*
representation (packed codes + f16 side info), whose rows are entangled by
the randomized Hadamard transform — a RaanA weight cannot be row-sharded
without re-quantizing per shard, but its output columns are mutually
independent (each column owns its packed codes, its ``rescale`` entry and
its ``w_out`` outlier column).  So every sharded weight is **column-
(output-) sharded** and the TP boundary is an ``all_gather`` of disjoint
output slices, never a ``psum`` of partial products:

  * attention ``wq``/``wk``/``wv`` shard by head over ``"model"`` (and the
    KV block arena shards its head axis to match); ``wo`` stays replicated
    and consumes the head-gathered attention output,
  * the fused gate|up ``wi`` (dense, MoE expert, and shared-expert) shards
    by FFN column — with a placement-time column permutation to per-shard
    ``[gate_i | up_i]`` blocks so the local ``split(gu, 2)`` stays correct —
    and ``wo`` stays replicated behind a hidden-state gather,
  * ``lm_head`` shards the vocab and the logits gather once per step.

Replicating the row-parallel weights costs memory Megatron would shard, but
buys the property the serving tests pin: every shard computes bit-identical
per-column math to the single-device engine (no cross-shard float
reduction anywhere), so greedy outputs are token-identical at every TP
degree and ONE quantization artifact serves all of them.

A dimension that does not divide the ``"model"`` axis degrades to
replication (``sharding._fit``), and attention shards only when *both*
``n_heads`` and ``n_kv`` divide — ``wq``/``wk``/``wv`` and the arena must
agree on the GQA group ratio.  Everything dynamic that the scheduler churns
(block tables, positions, active masks) plus all host-side ownership state
(allocator, prefix cache) stays replicated/host-side; the gather helpers
below are shape-driven no-ops whenever the local dim is already full, so
the single-device engine is literally the TP=1 special case of the same
code path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .sharding import _fit

AXIS = "model"
_R = P()


# ------------------------------------------------------------------- mesh


def default_mesh() -> Mesh:
    """The trivial (1, 1) serving mesh — TP=1 as the degenerate case of the
    sharded path, so the engine has exactly one code path."""
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


# ------------------------------------------------------------------- plan


@dataclasses.dataclass(frozen=True)
class TPPlan:
    """Which weight families actually shard at this TP degree (the rest
    replicate).  Attention is all-or-nothing: ``wq``/``wk``/``wv`` and the
    KV arena shard together or not at all, so the GQA group ratio is the
    same on every shard."""
    tp: int
    attn: bool       # wq/wk/wv by head + KV arena head axis
    ffn: bool        # dense glu/gelu wi by FFN column
    moe: bool        # expert wi by per-expert FFN column
    shared: bool     # shared-expert swi by FFN column
    lm_head: bool    # vocab columns (logits gathered once per step)

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


def plan_for(cfg, tp: int) -> TPPlan:
    moe = cfg.moe
    return TPPlan(
        tp=tp,
        attn=tp > 1 and cfg.n_heads % tp == 0 and cfg.n_kv % tp == 0,
        ffn=tp > 1 and cfg.moe is None and cfg.d_ff % tp == 0,
        moe=tp > 1 and moe is not None and moe.d_ff_expert % tp == 0,
        shared=(tp > 1 and moe is not None and moe.n_shared > 0
                and (moe.d_ff_expert * moe.n_shared) % tp == 0),
        lm_head=tp > 1 and cfg.vocab % tp == 0)


# ------------------------------------------- trace-time gather helpers


def gather_heads(x: jax.Array, full_heads: int) -> jax.Array:
    """(..., H_loc, hd) -> (..., H, hd): concatenate per-shard head slices
    over ``"model"``.  Shape-driven: a no-op when the heads are already
    full (TP=1 or replication fallback), so callers need no TP flag."""
    if x.shape[-2] == full_heads:
        return x
    return jax.lax.all_gather(x, AXIS, axis=x.ndim - 2, tiled=True)


def gather_cols(y: jax.Array, full_dim: int) -> jax.Array:
    """(..., c_loc) -> (..., c): concatenate per-shard column slices over
    ``"model"`` (FFN hidden states, logits).  No-op when already full."""
    if y.shape[-1] == full_dim:
        return y
    return jax.lax.all_gather(y, AXIS, axis=y.ndim - 1, tiled=True)


def in_dim(w) -> int:
    """Full input width of a 2-D weight (array or QuantizedLinear — both
    expose ``.shape`` as the logical (d_in, d_out))."""
    return w.shape[0]


# ----------------------------------------------- param specs + placement

# column-sharded weight keys, gated by the plan flag that owns them; the
# quantized-leaf fields of a sharded weight that slice along the column
# axis (everything else — signs, outlier indices, mean column — replicates)
_SHARDED_FIELDS = {"packed", "rescale", "w_out"}
_REPLICATED_FIELDS = {"signs1", "signs2", "mean_col", "out_idx", "keep_idx"}


def _path_names(path) -> list[str]:
    return [str(getattr(e, "key", getattr(e, "name", ""))) for e in path]


def _decision(plan: TPPlan, names: list[str]):
    """(shard, glu_permute) for the weight node owning this leaf."""
    if "attn" in names and any(k in names for k in ("wq", "wk", "wv")):
        return plan.attn, False
    if "swi" in names:
        return plan.shared, True
    if "wi" in names:
        if "moe" in names:
            return plan.moe, True
        if "mlp" in names:
            # permute only for fused gate|up layouts — prepare_params
            # drops the flag for plain-gelu (whisper) archs
            return plan.ffn, True
    if "lm_head" in names:
        return plan.lm_head, False
    return False, False


def _glu_perm(two_f: int, tp: int) -> np.ndarray:
    """Column permutation taking a fused [gate | up] layout (2f columns) to
    interleaved per-shard [gate_i | up_i] blocks, so shard i's local
    ``split(gu, 2, axis=-1)`` yields exactly gate/up columns
    [i*f/tp, (i+1)*f/tp) and the gathered hidden state lands in natural
    column order.  Exact for quantized leaves too: packed codes, rescale
    and outlier rows are all per-column."""
    f = two_f // 2
    fl = f // tp
    return np.concatenate([
        np.concatenate([np.arange(i * fl, (i + 1) * fl),
                        f + np.arange(i * fl, (i + 1) * fl)])
        for i in range(tp)])


def _leaf_spec(plan: TPPlan, names: list[str], leaf, mesh: Mesh):
    """(PartitionSpec, permute_cols) for one param leaf."""
    nd = getattr(leaf, "ndim", 0)
    shard, glu = _decision(plan, names)
    if not shard or nd == 0:
        return P(*([None] * nd)), False
    field = names[-1]
    if field in _REPLICATED_FIELDS:
        return P(*([None] * nd)), False
    # raw weight arrays and the column-sliced quantized fields all shard
    # their last (output-column) axis
    spec = _fit(P(*([None] * (nd - 1)), AXIS), leaf.shape, mesh)
    if spec[-1] is None:      # _fit dropped it: dim doesn't divide
        return spec, False
    return spec, glu


def prepare_params(cfg, params: Any, mesh: Mesh):
    """Shard-place a (possibly quantized) param tree for TP serving.

    Returns ``(placed_params, spec_list)`` where ``spec_list`` is ordered
    like ``jax.tree.flatten(params)`` — the in_specs the engine's
    ``shard_map`` wrapper uses.  Weights that shard get ``device_put`` with
    a column sharding (after the gate/up interleaving permutation for fused
    glu ``wi``); everything else replicates across the whole mesh.
    """
    tp = int(mesh.shape[AXIS])
    plan = plan_for(cfg, tp)
    glu_ffn = cfg.ffn_kind() != "gelu"   # fused gate|up wi (glu/moe archs)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs, placed = [], []
    for path, leaf in flat:
        names = _path_names(path)
        spec, permute = _leaf_spec(plan, names, leaf, mesh)
        if permute and glu_ffn:
            perm = _glu_perm(int(leaf.shape[-1]), tp)
            leaf = jnp.take(leaf, jnp.asarray(perm), axis=-1)
        specs.append(spec)
        placed.append(jax.device_put(leaf, NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, placed), specs


def cache_spec_list(caches: Any, mesh: Mesh, plan: TPPlan) -> list[P]:
    """Specs for the pool cache tree, ordered like its flatten order: the
    attention block arenas (n_j, N, bs, KV, hd) shard their KV-head axis
    when the plan shards attention; per-slot recurrent/MLA state and
    everything else replicates (block tables never reach device state —
    they are step *arguments*, replicated like the rest of the scheduler's
    churn)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(caches)
    specs = []
    for path, leaf in flat:
        names = _path_names(path)
        if plan.attn and leaf.ndim == 5 and names and names[-1] in ("k", "v"):
            specs.append(_fit(P(None, None, None, AXIS, None),
                              leaf.shape, mesh))
        else:
            specs.append(P(*([None] * leaf.ndim)))
    return specs


def place(tree: Any, spec_list: list[P], mesh: Mesh):
    """device_put each leaf of ``tree`` with its spec (flatten order)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    placed = [jax.device_put(l, NamedSharding(mesh, s))
              for l, s in zip(leaves, spec_list)]
    return jax.tree_util.tree_unflatten(treedef, placed)


# ------------------------------------------------------ shard_map wrapper


def sharded_call(core: Callable, mesh: Mesh, pspecs: list[P],
                 cspecs: list[P]) -> Callable:
    """Wrap ``core(params, caches, *arrays) -> (out, new_caches)`` in a
    ``shard_map`` over ``mesh``.

    Trees are flattened at the boundary so in/out specs are plain tuples of
    ``PartitionSpec`` (quantized param trees carry static dataclass
    metadata that spec-tree prefix matching would trip over).  All step
    arguments and the output are replicated; caches go in and come out
    under the same specs, so jit donation of the pool buffers survives the
    wrapper.  ``check_rep=False``: the output IS replicated by construction
    (every shard finishes with fully-gathered activations) but shard_map
    cannot prove it through ``all_gather``-of-disjoint-slices."""
    psp, csp = tuple(pspecs), tuple(cspecs)

    def call(params, caches, *arrays):
        pl, _ = jax.tree_util.tree_flatten(params)
        cl, ctd = jax.tree_util.tree_flatten(caches)
        ptd = jax.tree_util.tree_structure(params)

        def body(pl_, cl_, arrs):
            p = jax.tree_util.tree_unflatten(ptd, pl_)
            c = jax.tree_util.tree_unflatten(ctd, cl_)
            out, nc = core(p, c, *arrs)
            return out, tuple(jax.tree_util.tree_flatten(nc)[0])

        out, ncl = shard_map(
            body, mesh=mesh, in_specs=(psp, csp, _R),
            out_specs=(_R, csp), check_rep=False)(
                tuple(pl), tuple(cl), tuple(arrays))
        return out, jax.tree_util.tree_unflatten(ctd, list(ncl))

    return call


def sharded_cache_op(core: Callable, mesh: Mesh, cspecs: list[P]) -> Callable:
    """Like ``sharded_call`` for cache-only ops (the copy-on-write block
    clone): ``core(caches, *arrays) -> new_caches`` under the cache specs."""
    csp = tuple(cspecs)

    def call(caches, *arrays):
        cl, ctd = jax.tree_util.tree_flatten(caches)

        def body(cl_, arrs):
            nc = core(jax.tree_util.tree_unflatten(ctd, cl_), *arrs)
            return tuple(jax.tree_util.tree_flatten(nc)[0])

        ncl = shard_map(body, mesh=mesh, in_specs=(csp, _R), out_specs=csp,
                        check_rep=False)(tuple(cl), tuple(arrays))
        return jax.tree_util.tree_unflatten(ctd, list(ncl))

    return call
