"""Opt-in activation sharding constraints (perf variants, §Perf).

``POLICY["hidden"]`` — a PartitionSpec applied to the (B, S, d) hidden states
after embedding and after every layer.  Sequence sharding over the model axis
(P(dp, "model", None)) turns prefill into sequence-parallel execution: norms
and MLPs run on S/16 shards and the partitioner materializes gathers only
around attention, instead of resharding ad hoc per op.
Module-level (not threaded through model code) because it is a launcher
decision, set once before lowering.
"""
from __future__ import annotations

import jax

POLICY: dict = {}


def shard_named(x, key: str):
    spec = POLICY.get(key)
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def shard_hidden(h):
    return shard_named(h, "hidden")
