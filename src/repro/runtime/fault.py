"""Fault tolerance & straggler mitigation for the training loop.

A 1000+-node fleet sees preemptions, flaky hosts, and stragglers as routine.
This module provides the host-side control plane:

  * ``FaultTolerantLoop`` — wraps the jitted step with: periodic checkpoint
    (async), automatic resume from the latest checkpoint, bounded retry on
    transient step failure, and a straggler watchdog (per-step deadline
    derived from a trailing median; violations are logged and, after K
    consecutive, trigger a checkpoint so a scheduler can evict the slow
    host).  On a single-host container failures are injected by tests via
    ``inject_failure``.
  * elasticity: since checkpoints are host-numpy (checkpoint/ckpt.py), resume
    onto a different mesh/pod count re-shards transparently; the data loader
    keys batches by (step, host_count) so the sample stream stays coherent.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.checkpoint import CheckpointManager

log = logging.getLogger("repro.fault")


@dataclass
class LoopConfig:
    ckpt_every: int = 50
    max_retries: int = 2
    straggler_factor: float = 3.0     # deadline = factor * trailing median
    straggler_window: int = 20
    straggler_patience: int = 3


@dataclass
class LoopStats:
    step_times: list = field(default_factory=list)
    straggler_events: int = 0
    retries: int = 0
    resumed_from: Optional[int] = None


class FaultTolerantLoop:
    def __init__(self, step_fn: Callable, ckpt: CheckpointManager,
                 cfg: LoopConfig = LoopConfig(),
                 inject_failure: Optional[Callable[[int], bool]] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.cfg = cfg
        self.stats = LoopStats()
        self.inject_failure = inject_failure
        self._slow_streak = 0

    def maybe_resume(self, state: Any) -> tuple[Any, int]:
        """Restore (state, start_step) from the latest checkpoint if any."""
        latest = self.ckpt.latest_step()
        if latest is None:
            return state, 0
        restored, extra = self.ckpt.restore(latest, state)
        self.stats.resumed_from = latest
        log.info("resumed from checkpoint step %d", latest)
        return restored, int(extra.get("next_step", latest))

    def _deadline(self) -> Optional[float]:
        times = self.stats.step_times[-self.cfg.straggler_window:]
        if len(times) < 5:
            return None
        med = sorted(times)[len(times) // 2]
        return self.cfg.straggler_factor * med

    def run(self, state: Any, batches: Callable[[int], Any], n_steps: int,
            start_step: int = 0, on_metrics: Optional[Callable] = None):
        """Run steps [start_step, n_steps) with checkpoint/restart/watchdog."""
        step = start_step
        while step < n_steps:
            batch = batches(step)
            t0 = time.time()
            attempt = 0
            while True:
                try:
                    if self.inject_failure and self.inject_failure(step):
                        raise RuntimeError(f"injected failure at step {step}")
                    state, metrics = self.step_fn(state, batch)
                    break
                except Exception as e:  # transient failure path
                    attempt += 1
                    self.stats.retries += 1
                    log.warning("step %d failed (%s), retry %d", step, e,
                                attempt)
                    if attempt > self.cfg.max_retries:
                        # hard failure: persist and resume from checkpoint
                        latest = self.ckpt.latest_step()
                        if latest is None:
                            raise
                        state, extra = self.ckpt.restore(latest, state)
                        step = int(extra.get("next_step", latest))
                        batch = batches(step)
                        attempt = 0
            dt = time.time() - t0
            deadline = self._deadline()
            if deadline is not None and dt > deadline:
                self.stats.straggler_events += 1
                self._slow_streak += 1
                log.warning("straggler: step %d took %.3fs (deadline %.3fs)",
                            step, dt, deadline)
                if self._slow_streak >= self.cfg.straggler_patience:
                    log.warning("straggler streak — checkpointing for "
                                "eviction/reschedule")
                    self.ckpt.save(step, state, {"next_step": step + 1})
                    self._slow_streak = 0
            else:
                self._slow_streak = 0
            self.stats.step_times.append(dt)
            if on_metrics:
                on_metrics(step, metrics, dt)
            step += 1
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save(step, state, {"next_step": step})
        self.ckpt.save(n_steps, state, {"next_step": n_steps})
        self.ckpt.wait()
        return state
