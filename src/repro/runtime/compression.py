"""Gradient compression for cross-pod all-reduce bandwidth.

Two pieces:
  * ``compress_decompress_grads`` — int8 per-tensor symmetric quantization of
    gradients applied inside the jitted step.  Under GSPMD the all-reduce
    happens on the *compressed-then-decompressed* values; the compression
    models the quality impact (what matters for convergence testing).  On a
    real fleet the same transform pairs with a shard_map all-reduce over int8
    payloads (see ``int8_psum`` below) for the actual 4x wire saving.
  * ``ErrorFeedback`` — residual accumulation so quantization error is
    re-injected next step (1-bit Adam / EF-SGD style), keeping convergence
    close to exact all-reduce even at int8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_int8(g: jax.Array):
    a = jnp.max(jnp.abs(g))
    scale = jnp.maximum(a, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compress_decompress_grads(grads):
    def one(g):
        if g.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
            return g
        q, s = _quantize_int8(g.astype(jnp.float32))
        return _dequantize_int8(q, s).astype(g.dtype)
    return jax.tree.map(one, grads)


class ErrorFeedback:
    """Stateful error-feedback wrapper (host-side pytree of residuals)."""

    def __init__(self, params_like):
        self.residual = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_like)

    def apply(self, grads):
        def one(g, r):
            gf = g.astype(jnp.float32) + r
            q, s = _quantize_int8(gf)
            deq = _dequantize_int8(q, s)
            return deq.astype(g.dtype), gf - deq
        out = jax.tree.map(one, grads, self.residual)
        grads_c = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        self.residual = jax.tree.map(lambda t: t[1], out,
                                     is_leaf=lambda x: isinstance(x, tuple))
        return grads_c


def int8_psum(x: jax.Array, axis_name: str):
    """shard_map building block: all-reduce an int8-quantized payload.

    Quantize -> psum int32 (wire: 1B/elem payload + 4B accumulator semantics;
    on TPU the reduce runs over the int payload) -> rescale by the max of the
    per-shard scales.  Unbiased up to the shared-scale approximation.
    """
    q, s = _quantize_int8(x.astype(jnp.float32))
    s_max = jax.lax.pmax(s, axis_name)
    # re-quantize against the shared scale so the integer sum is coherent
    q2 = jnp.clip(jnp.round(x.astype(jnp.float32) / s_max), -127, 127
                  ).astype(jnp.int32)
    total = jax.lax.psum(q2, axis_name)
    return total.astype(jnp.float32) * s_max
