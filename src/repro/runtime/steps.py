"""Step builders: microbatched train_step (grad accumulation + remat),
serve prefill/decode steps — the functions the launcher jits/lowers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import decode as decmod
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.optim import adamw_update
from repro.optim.schedule import cosine_schedule

from .compression import compress_decompress_grads


def make_train_step(cfg: ModelConfig, *, microbatches: int = 1,
                    peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000, remat: bool = True,
                    grad_compression: Optional[str] = None,
                    mesh=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Gradient accumulation: the global batch is split into ``microbatches``
    chunks scanned sequentially — peak activation memory drops by ~that factor
    (the knob that lets train_4k's 256 x 4096 x vocab logits fit per chip).
    Per-microbatch forward is remat'd (activation checkpointing at the loss
    boundary); layer-level remat comes from scan-over-layers + jax.remat in
    the loss when enabled.
    """
    loss_fn = functools.partial(tf.loss_fn, cfg)
    if remat:
        loss_fn = jax.checkpoint(loss_fn, static_argnums=())

    def compute_grads(params, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, grads

        def split(path, x):
            key = str(getattr(path[-1], "key", ""))
            if key == "positions":               # mrope (3, B, S)
                return x.reshape(3, microbatches, -1, *x.shape[2:]
                                 ).transpose(1, 0, 2, 3)
            return x.reshape(microbatches, -1, *x.shape[1:])

        mb = jax.tree_util.tree_map_with_path(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mbatch):
            loss_acc, gacc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
            gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                gacc, grads)
            return (loss_acc + loss, gacc), None

        (loss, gsum), _ = jax.lax.scan(body, (jnp.float32(0.0), zero), mb)
        scale = 1.0 / microbatches
        return loss * scale, jax.tree.map(lambda g: g * scale, gsum)

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        if grad_compression == "int8":
            grads = compress_decompress_grads(grads)
        lr = cosine_schedule(opt_state.step, peak_lr, warmup, total_steps)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, context: int, cache_dtype=jnp.bfloat16):
    def prefill_step(params, batch):
        logits, caches, pos = decmod.prefill(
            cfg, params, batch.get("tokens"), positions=batch.get("positions"),
            enc_embeds=batch.get("enc_embeds"), context=context,
            cache_dtype=cache_dtype, scan=True)
        return logits[:, -1, :], caches
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One-token decode: (params, caches, tokens (B,1), pos) -> logits, caches."""
    def serve_step(params, caches, tokens, pos):
        return decmod.decode_step(cfg, params, caches, tokens, pos, scan=True)
    return serve_step
