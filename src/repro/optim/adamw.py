"""AdamW in pure JAX.  Moments are pytrees mirroring params, so they inherit
whatever sharding the params carry (ZeRO falls out of the sharding rules in
runtime/sharding.py — moments shard over data AND model axes)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array   # scalar int32
    mu: dict          # first moment (f32)
    nu: dict          # second moment (f32)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_grad_norm: float = 1.0):
    """One AdamW step; returns (new params, new state, grad norm)."""
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mhat = m / b1c
        vhat = v / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * pf)
        return pf.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), gnorm
