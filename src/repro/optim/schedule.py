"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, peak_lr: float, warmup: int):
    return peak_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))


def cosine_schedule(step, peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    warm = linear_warmup(step, peak_lr, warmup)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, peak_lr * cos)
