"""Continuous-batching LM serving engine over the paged KV-cache pool.

Scheduler loop (one iteration): admit queued requests while slots and blocks
are free, run ONE prompt chunk for the oldest mid-prefill request, then run
ONE decode step over the whole slot set.  Chunked prefill therefore
interleaves with decode instead of stalling it, and a request that hits EOS
or its token budget frees its slot and blocks immediately, so queued
requests join mid-flight — nobody waits for a batch to drain (the lockstep
failure mode ``launch/serve.BatchedServer`` keeps around as the A/B
baseline).

The decode step is jitted ONCE per engine: batch-composition churn only
changes the *contents* of (tokens, pos, active, block_tables, ring_cap)
arrays, never their shapes, so quantized weights stay resident and decode
occupancy is limited by traffic, not recompilation
(``decode_trace_count`` is asserted == 1 in tests/test_paged_engine.py).

Admission consults the content-addressed prefix cache (DESIGN.md §8): the
longest cached prefix of the prompt is served straight from the pool
(refcounts bumped, chunked prefill starts at the first uncached token, a
mid-block match is cloned copy-on-write), and completed requests *release*
their blocks — fully-written blocks stay cached on an LRU that is evicted
only under allocation pressure.  Pure-attention, non-windowed archs only;
ring-window blocks mutate in place and recurrent/MLA state is per-slot, so
those configs bypass the cache entirely.

Self-speculative decoding (DESIGN.md §9): with ``draft_params`` (a second,
aggressively low-bit quantization of the SAME weights — see
``core.pipeline.quantize_model_dual``) and ``speculate=k``, the decode
phase becomes draft-propose / target-verify: the draft decodes k tokens
autoregressively through its own KV arena (same block tables as the
target's, so prefix hits warm both), the target scores all k+1 positions in
one batched ``decode_verify_paged`` step, and the standard rejection-
sampling acceptance rule emits between 1 and k+1 tokens per round while
preserving the target distribution exactly (greedy mode is token-identical
to non-speculative decoding).  Attention archs only; recurrent/MLA archs
bypass speculation because their sequential per-slot state cannot absorb
rejected positions.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention import ops as pops
from repro.kernels.qmatmul import ops as qops
from repro.models import decode as decmod
from repro.models.config import ModelConfig
from repro.runtime import tp as tpmod

from .pool import (BlockAllocator, PoolConfig, PrefixCache, init_pool_caches,
                   request_blocks)


@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival`` is seconds after engine-clock
    start (workload simulation / HTTP arrival time); the engine will not
    admit it earlier.  ``tenant`` / ``priority`` / ``deadline`` are
    scheduling metadata the engine itself ignores — the front-door
    ``Scheduler`` (serve/frontdoor, DESIGN.md §12) orders admission and
    picks preemption victims by them.  ``on_token`` (if set) is called as
    ``on_token(rid, token, t)`` from the serving thread the moment each
    token is emitted — the streaming hook the SSE server bridges onto an
    asyncio queue; it must be cheap and must not raise."""
    rid: int
    prompt: np.ndarray               # (plen,) int32
    max_new: int
    eos: Optional[int] = None
    arrival: float = 0.0
    tenant: str = "default"
    priority: int = 0                # higher = more urgent
    deadline: Optional[float] = None  # engine-clock seconds (SLO metadata)
    on_token: Optional[Callable[[int, int, float], None]] = None


@dataclasses.dataclass
class RequestResult:
    """Completion record for one request: the generated tokens plus the
    admission / first-token / completion timestamps (engine-clock seconds)
    the serving benchmarks turn into latency percentiles.  ``ttft_s`` is
    time-to-first-token measured from the request's *arrival* (queueing
    included), ``token_times`` the engine-clock emission time of every
    generated token, and ``preemptions`` how many times the request was
    drop-and-replay preempted (its timestamps span incarnations: ``t_admit``
    / ``t_first`` are from the first, ``t_done`` from the last)."""
    rid: int
    tokens: np.ndarray               # generated tokens (<= max_new)
    t_admit: float                   # engine-clock seconds
    t_first: float                   # first generated token
    t_done: float
    ttft_s: float = 0.0              # t_first - arrival
    token_times: np.ndarray = None   # (len(tokens),) emission times
    preemptions: int = 0
    tenant: str = "default"


@dataclasses.dataclass
class _Replay:
    """Continuation state of a preempted request, keyed by rid until the
    scheduler resubmits it: the tokens already emitted (replayed as extra
    prompt) and the first-incarnation timestamps."""
    prior: list
    t_admit: float
    t_first: float
    token_times: list
    preemptions: int


@dataclasses.dataclass
class _InFlight:
    req: Request
    slot: int
    blocks: list
    bt_row: np.ndarray               # (MB,) int32 physical block ids
    ring_cap: int                    # tokens; ring for windowed archs
    served: np.ndarray = None        # prompt + replayed tokens actually fed
    filled: int = 0                  # served tokens prefilled so far
    out: list = dataclasses.field(default_factory=list)
    prior: list = dataclasses.field(default_factory=list)  # pre-preemption
    token_times: list = dataclasses.field(default_factory=list)
    preemptions: int = 0
    t_admit: float = 0.0
    t_first: float = 0.0
    chain: object = None             # prefix-cache hash of last full block
    n_hashed: int = 0                # full blocks matched/registered so far
    draft_pos: int = 0               # draft-KV-valid positions (speculation)

    @property
    def n_done(self) -> int:
        """Tokens emitted across all incarnations (sampling step index)."""
        return len(self.prior) + len(self.out)


def speculative_accept(target_logits: np.ndarray, draft_logits: np.ndarray,
                       draft_tokens: np.ndarray, temperature: float,
                       rng: np.random.Generator):
    """Standard speculative-sampling acceptance rule for one slot's round.

    ``target_logits`` (k+1, V) are the target model's logits at the k+1
    verified positions (last accepted token + k draft tokens);
    ``draft_logits`` (k, V) are the logits each ``draft_tokens[i]`` was
    sampled from.  Greedy (``temperature <= 0``): accept ``d_i`` while it
    equals the target argmax at its position, emit the target argmax at the
    first mismatch, emit the bonus argmax after a full accept — every
    emitted token is a target argmax, so greedy speculation is
    token-identical to non-speculative decoding.  Sampling
    (``temperature > 0``): accept ``d_i`` with probability
    ``min(1, p_t(d_i) / p_d(d_i))``, on rejection sample from the residual
    ``normalize(max(p_t - p_d, 0))``, after a full accept sample the bonus
    from the target's last distribution — the marginal distribution of
    emitted tokens equals target-only sampling (Leviathan et al., 2023;
    pinned statistically in tests/test_speculative.py).  Returns
    ``(tokens, n_accepted)`` with ``len(tokens) == n_accepted + 1``.
    """
    k = len(draft_tokens)
    out: list[int] = []
    if temperature <= 0.0:
        for i in range(k):
            t_star = int(np.argmax(target_logits[i]))
            out.append(t_star)
            if int(draft_tokens[i]) != t_star:
                return out, i
        out.append(int(np.argmax(target_logits[k])))
        return out, k

    def dist(logits):
        z = logits.astype(np.float64) / temperature
        e = np.exp(z - z.max())
        return e / e.sum()

    for i in range(k):
        p_t, p_d = dist(target_logits[i]), dist(draft_logits[i])
        d = int(draft_tokens[i])
        if rng.random() < min(1.0, p_t[d] / max(p_d[d], 1e-300)):
            out.append(d)
            continue
        resid = np.maximum(p_t - p_d, 0.0)
        s = resid.sum()
        p = resid / s if s > 0.0 else p_t
        out.append(int(rng.choice(p.size, p=p)))
        return out, i
    p_t = dist(target_logits[k])
    out.append(int(rng.choice(p_t.size, p=p_t)))
    return out, k


class PagedServer:
    """Continuous-batching engine over the paged KV pool; greedy or
    temperature sampling.

    ``fused`` selects the RHT+qmatmul fusion for every traced function of
    this engine via the scoped ``qops.fusion`` context (fixed per engine —
    each jitted step is traced under it exactly once).  ``paged_kernel``
    likewise pins the attention read: True routes every paged attention
    (decode / catch-up / verify) through the Pallas flash-decode kernel
    over the block arena (interpret-mode off TPU), False through the dense
    gather reference, and None (default) lets the backend decide — kernel
    on TPU, gather elsewhere (DESIGN.md §10).  ``draft_params`` +
    ``speculate=k`` turn on self-speculative decoding (draft proposes k
    tokens, target verifies them in one batched step; see the module
    docstring and DESIGN.md §9); recurrent/MLA archs silently bypass
    speculation and run the plain decode loop.  Construct once per (model,
    PoolConfig) — all serving state (arenas, allocator, queues, stats)
    lives on the instance, and ``run`` drains a workload to completion.

    ``mesh`` (a ``("data", "model")`` mesh, e.g. from
    ``launch.mesh.make_host_mesh(tp=2)``) turns on tensor-parallel serving
    (DESIGN.md §11): params are column-shard-placed per ``runtime.tp``'s
    plan, the KV block arenas shard their head axis, and every jitted step
    runs inside one ``shard_map`` over the mesh.  Default is the trivial
    (1, 1) mesh — single-device serving is the TP=1 special case of the
    same code path, not a separate one.  Scheduler/allocator/prefix-cache
    state stays host-side and replicated regardless of TP degree.
    """

    def __init__(self, cfg: ModelConfig, params: dict,
                 pool: PoolConfig | None = None, *, fused: bool = True,
                 paged_kernel: bool | None = None,
                 temperature: float = 0.0, seed: int = 0,
                 draft_params: dict | None = None, speculate: int = 0,
                 mesh=None):
        if cfg.enc_dec:
            raise ValueError(
                "PagedServer does not support encoder-decoder archs")
        if speculate < 0:
            raise ValueError(f"speculate must be >= 0 (got {speculate})")
        if speculate and draft_params is None:
            raise ValueError("speculate > 0 requires draft_params "
                             "(see core.pipeline.quantize_model_dual)")
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else tpmod.default_mesh()
        self.tp = int(self.mesh.shape[tpmod.AXIS])
        self.tp_plan = tpmod.plan_for(cfg, self.tp)
        self.params, self._pspecs = tpmod.prepare_params(cfg, params,
                                                         self.mesh)
        self.pool = pool or PoolConfig()
        self.fused = fused
        self.paged_kernel = paged_kernel
        self.temperature = temperature
        self.seed = seed
        # Speculation needs KV that is addressable by absolute position so
        # rejected tokens can simply be overwritten; sequential per-slot
        # state (RWKV/RG-LRU/MLA latents) cannot roll back, so those archs
        # bypass and serve through the plain decode loop (DESIGN.md §9).
        self.speculating = bool(speculate) and all(
            mx == "attn" for mx in cfg.pattern)
        self.speculate = speculate if self.speculating else 0
        if self.speculating:
            self.draft_params, self._draft_pspecs = tpmod.prepare_params(
                cfg, draft_params, self.mesh)
        else:
            self.draft_params, self._draft_pspecs = None, None
        if self.speculating and self.pool.lookahead < speculate:
            # verify/draft steps write up to `speculate` positions past the
            # accepted frontier; reserve ring capacity so those writes can
            # never wrap onto live history (window or prompt)
            self.pool = dataclasses.replace(self.pool, lookahead=speculate)
        # KV arenas shard their head axis when the plan shards attention;
        # recurrent/MLA slot state replicates (runtime/tp.py).
        self.caches = init_pool_caches(cfg, params, self.pool)
        self._cspecs = tpmod.cache_spec_list(self.caches, self.mesh,
                                             self.tp_plan)
        self.caches = tpmod.place(self.caches, self._cspecs, self.mesh)
        if self.speculating:
            dc = init_pool_caches(cfg, draft_params, self.pool)
            self.draft_caches = tpmod.place(dc, self._cspecs, self.mesh)
        else:
            self.draft_caches = None
        # Prefix caching needs blocks that are immutable once written:
        # pure-attention archs without a sliding window.  Windowed archs
        # ring-reuse their blocks in place, and recurrent/MLA state lives in
        # per-slot arrays the cache can't name — both bypass.
        self.cacheable = (self.pool.prefix_cache and cfg.window is None
                          and all(mx == "attn" for mx in cfg.pattern))
        self.prefix_cache = (PrefixCache(self.pool.block_size)
                             if self.cacheable else None)
        self.allocator = BlockAllocator(self.pool.resolved_num_blocks(cfg),
                                        cache=self.prefix_cache)
        self.free_slots = list(range(self.pool.max_slots - 1, -1, -1))
        self.table_width = max(
            request_blocks(cfg, self.pool, self.pool.max_context), 1)
        self.has_attn = "attn" in cfg.pattern
        self.decode_trace_count = 0
        self.draft_trace_count = 0        # single-token draft steps
        self.catchup_trace_count = 0      # 2-token draft catch-up steps
        self.verify_trace_count = 0       # (k+1)-token target verify steps
        self.stats: dict = {}
        self._pending: collections.deque[Request] = collections.deque()
        self._prefilling: collections.deque[_InFlight] = collections.deque()
        self._active: dict[int, _InFlight] = {}
        self._replay: dict[int, _Replay] = {}
        self._t0: float | None = None
        self._last_decode_end: float | None = None
        # Gap between the ends of consecutive decode steps — the per-token
        # decode latency a request actually observes, inflated by whatever
        # (chunked prefill, admission work) the scheduler interleaves.  The
        # front-door SLO controller reads the tail of this window.
        self.decode_gaps: collections.deque = collections.deque(maxlen=2048)

        # Caches are donated: the pool buffers alias input->output instead of
        # being copied every step (same pattern as launch/dryrun.py).  jit's
        # own shape cache handles the few distinct prefill chunk lengths.
        # Every step runs inside ONE shard_map over the engine mesh
        # (runtime/tp.sharded_call): params/caches enter under their
        # placement specs, step arguments and logits replicate, and cache
        # in/out specs match so donation survives the wrapper.  The draft
        # steps get their own wrappers because the draft quantization has
        # its own param spec list.
        def _wrap(core, pspecs):
            return tpmod.sharded_call(core, self.mesh, pspecs, self._cspecs)

        step_core = _wrap(
            lambda p_, c_, *a: decmod.decode_step_paged(cfg, p_, c_, *a),
            self._pspecs)
        chunk_core = _wrap(
            lambda p_, c_, *a: decmod.prefill_chunk_paged(cfg, p_, c_, *a),
            self._pspecs)
        verify_core = _wrap(
            lambda p_, c_, *a: decmod.decode_verify_paged(cfg, p_, c_, *a),
            self._pspecs)
        if self.speculating:
            draft_step_core = _wrap(
                lambda p_, c_, *a: decmod.decode_step_paged(cfg, p_, c_, *a),
                self._draft_pspecs)
            draft_verify_core = _wrap(
                lambda p_, c_, *a: decmod.decode_verify_paged(cfg, p_, c_,
                                                              *a),
                self._draft_pspecs)

        def _step(params_, caches, tokens, pos, active, bts, ring):
            self.decode_trace_count += 1      # trace-time side effect only
            return step_core(params_, caches, tokens, pos, active, bts, ring)

        def _draft_step(params_, caches, tokens, pos, active, bts, ring):
            self.draft_trace_count += 1       # trace-time side effect only
            return draft_step_core(params_, caches, tokens, pos, active,
                                   bts, ring)

        def _chunk(params_, caches, toks, pos0, slot, bt, ring):
            return chunk_core(params_, caches, toks, pos0, slot, bt, ring)

        def _verify(params_, caches, tokens, pos0, active, bts, ring, wmask):
            self.verify_trace_count += 1      # trace-time side effect only
            return verify_core(params_, caches, tokens, pos0, active, bts,
                               ring, wmask)

        def _catchup(params_, caches, tokens, pos0, active, bts, ring, wmask):
            self.catchup_trace_count += 1     # trace-time side effect only
            return draft_verify_core(params_, caches, tokens, pos0, active,
                                     bts, ring, wmask)

        def _cow_core(caches, src, dst):
            # clone one physical block's KV across every layer arena
            return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), caches)

        _cow = tpmod.sharded_cache_op(_cow_core, self.mesh, self._cspecs)

        self._step = jax.jit(_step, donate_argnums=(1,))
        self._draft_step = jax.jit(_draft_step, donate_argnums=(1,))
        self._chunk = jax.jit(_chunk, donate_argnums=(1,))
        self._verify = jax.jit(_verify, donate_argnums=(1,))
        self._catchup = jax.jit(_catchup, donate_argnums=(1,))
        self._cow = jax.jit(_cow, donate_argnums=(0,))

    # ------------------------------------------------------------- plumbing

    @contextlib.contextmanager
    def _kernel_scope(self):
        """The engine's fixed kernel selections (RHT+qmatmul fusion, paged
        attention kernel-vs-gather), applied to every traced step — each
        jitted function keeps whatever it was traced under."""
        with qops.fusion(self.fused), pops.paged_kernel(self.paged_kernel):
            yield

    def _sample(self, logits: np.ndarray, rid: int, step: int) -> int:
        """One token from ``logits``: greedy argmax at temperature 0, else
        Gumbel-max sampling with a per-(request, step) deterministic RNG."""
        if self.temperature <= 0.0:
            return int(np.argmax(logits))
        rng = np.random.default_rng((self.seed, rid, step))
        g = rng.gumbel(size=logits.shape)
        return int(np.argmax(logits / self.temperature + g))

    def _draft_sample(self, logits: np.ndarray, rid: int, step: int,
                      i: int) -> int:
        """Draft proposal i of a speculative round: greedy argmax, or a
        sample from softmax(logits / T) — the exact distribution the
        acceptance rule divides by."""
        if self.temperature <= 0.0:
            return int(np.argmax(logits))
        rng = np.random.default_rng((self.seed, rid, step, i, 1))
        z = logits.astype(np.float64) / self.temperature
        e = np.exp(z - z.max())
        return int(rng.choice(e.size, p=e / e.sum()))

    # ------------------------------------------------------------ lifecycle

    def start_clock(self, reset: bool = False) -> None:
        """Pin the engine clock's zero (idempotent unless ``reset``).
        ``run`` resets it per call; a continuously-serving front door pins
        it once at boot.  Pass ``reset=True`` after warmup traffic so
        arrival offsets of a timed workload count from now, not from the
        warmup's clock."""
        if reset or self._t0 is None:
            self._t0 = time.monotonic()
            self._last_decode_end = None

    def now(self) -> float:
        """Seconds since the engine clock started (starts it if needed) —
        the time base of ``Request.arrival`` and every result timestamp."""
        self.start_clock()
        return time.monotonic() - self._t0

    def validate(self, req: Request) -> None:
        """Raise ValueError unless the request can ever be served by this
        pool — non-empty prompt, at least one generated token, and a total
        footprint (prompt + max_new, plus speculative lookahead) that fits
        ``max_context`` and the block arena.  The front door calls this at
        the HTTP boundary so a bad request 400s instead of poisoning the
        serving thread."""
        if len(req.prompt) < 1 or req.max_new < 1:
            raise ValueError(
                f"request {req.rid}: needs a non-empty prompt and "
                f"max_new >= 1 (got {len(req.prompt)}, {req.max_new})")
        total = len(req.prompt) + req.max_new
        if total > self.pool.max_context:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = {total} exceeds "
                f"max_context = {self.pool.max_context}")
        need = request_blocks(self.cfg, self.pool, total)
        if need > self.allocator.num_blocks - 1:
            raise ValueError(
                f"request {req.rid}: needs {need} blocks, pool has "
                f"{self.allocator.num_blocks - 1}")

    def submit(self, req: Request) -> None:
        """Queue a request for admission (it will not start before
        ``req.arrival``); validates via :meth:`validate` first."""
        self.validate(req)
        self._pending.append(req)

    def can_admit(self, req: Request) -> bool:
        """Whether admitting ``req`` right now would succeed: a free slot
        and enough allocatable blocks for its full capacity.  Conservative —
        prefix-cache hits can only reduce the fresh-block need (hit blocks
        are either free-listed, LRU-parked, or already referenced, and the
        first two are counted by ``free_blocks``)."""
        if not self.free_slots:
            return False
        need = request_blocks(self.cfg, self.pool,
                              len(req.prompt) + req.max_new)
        return need <= self.allocator.free_blocks

    def _try_admit(self, now: float) -> None:
        # FIFO with head-of-line blocking: admission control is purely
        # "do I have a slot and enough blocks for this request's capacity".
        # (Priority / fair-share ordering lives a layer up, in the
        # front-door Scheduler, which feeds this queue one admissible
        # request at a time.)
        while self._pending and self._pending[0].arrival <= now:
            req = self._pending[0]
            if not self.free_slots:
                return
            # A replayed (preempted) request re-feeds its already-emitted
            # tokens as extra prompt; its total footprint is unchanged
            # (prompt + max_new counts every token exactly once).
            rp = self._replay.get(req.rid)
            served = (np.concatenate([np.asarray(req.prompt, np.int32),
                                      np.asarray(rp.prior, np.int32)])
                      if rp and rp.prior else np.asarray(req.prompt, np.int32))
            total = len(req.prompt) + req.max_new
            need = request_blocks(self.cfg, self.pool, total)
            # Longest cached prefix: whole-block hits are shared (refcount
            # bumped before alloc so allocation pressure can't evict them);
            # a mid-block match is cloned copy-on-write into the request's
            # first private block.  Capped at plen - 1: at least one prompt
            # token is always recomputed to produce first-token logits.
            hits: list[int] = []
            parent, cached, cow_src = None, 0, None
            if self.prefix_cache is not None:
                hits, parent, cached, cow_src = self.prefix_cache.match(
                    served, len(served) - 1)
                for b in hits:
                    self.allocator.incref(b)
                if cow_src is not None:
                    self.allocator.incref(cow_src)
            fresh = self.allocator.alloc(need - len(hits))
            if fresh is None:
                if cow_src is not None:
                    self.allocator.decref(cow_src)
                for b in reversed(hits):      # leaf-first, like _finish
                    self.allocator.decref(b)
                return
            if cow_src is not None:
                # fresh[0] sits at logical index len(hits) — exactly where
                # the partially-matching block's contents belong
                self.caches = self._cow(self.caches, jnp.int32(cow_src),
                                        jnp.int32(fresh[0]))
                if self.speculating:
                    # the draft arena shares block tables: clone its copy too
                    self.draft_caches = self._cow(self.draft_caches,
                                                  jnp.int32(cow_src),
                                                  jnp.int32(fresh[0]))
                self.allocator.decref(cow_src)
                self.stats["prefix_cow"] = self.stats.get("prefix_cow", 0) + 1
            blocks = hits + fresh
            self._pending.popleft()
            self._replay.pop(req.rid, None)
            slot = self.free_slots.pop()
            bt_row = np.zeros(self.table_width, np.int32)
            bt_row[:need] = blocks
            ring_cap = len(blocks) * self.pool.block_size if blocks else 1
            if self.prefix_cache is not None:
                self.stats["prompt_tokens"] = (
                    self.stats.get("prompt_tokens", 0) + len(served))
                self.stats["prefill_tokens_saved"] = (
                    self.stats.get("prefill_tokens_saved", 0) + cached)
                if cached:
                    self.stats["prefix_hits"] = (
                        self.stats.get("prefix_hits", 0) + 1)
            if rp is not None:
                self.stats["replays"] = self.stats.get("replays", 0) + 1
            self._prefilling.append(_InFlight(
                req=req, slot=slot, blocks=blocks, bt_row=bt_row,
                ring_cap=ring_cap, served=served, filled=cached,
                prior=list(rp.prior) if rp else [],
                token_times=list(rp.token_times) if rp else [],
                preemptions=rp.preemptions if rp else 0,
                t_admit=rp.t_admit if rp else now,
                t_first=rp.t_first if rp else 0.0,
                chain=parent, n_hashed=len(hits), draft_pos=cached))

    def _register_blocks(self, st: _InFlight, seq, upto: int) -> None:
        """Register st's fully-written blocks covering positions < upto
        (KV for those positions is in the arena) into the prefix cache."""
        bs = self.pool.block_size
        while (st.n_hashed + 1) * bs <= upto:
            k = st.n_hashed
            st.chain = self.prefix_cache.register(
                st.chain, seq[k * bs:(k + 1) * bs], int(st.bt_row[k]))
            st.n_hashed += 1

    def _emit(self, st: _InFlight, tok: int, now: float) -> None:
        """One token leaves the engine: record it (and its emission time),
        stamp TTFT on the request's very first token, and fire the
        streaming callback."""
        st.out.append(int(tok))
        st.token_times.append(now)
        if st.t_first == 0.0 and not st.prior:
            st.t_first = now
        if st.req.on_token is not None:
            st.req.on_token(st.req.rid, int(tok), now)

    def _finish(self, st: _InFlight, now: float,
                results: dict[int, RequestResult]) -> None:
        if self.prefix_cache is not None:
            # decode wrote KV through position len(served) + len(out) - 2
            # (the last sampled token was never fed back), so generated
            # tokens extend the cached chain too — multi-turn prompts hit
            # their history
            seq = np.concatenate([st.served,
                                  np.asarray(st.out[:-1], np.int32)])
            self._register_blocks(st, seq, len(seq))
        # children (later blocks) enter the idle LRU first, so eviction
        # under pressure reclaims leaves before the prefixes they chain off
        for b in reversed(st.blocks):
            self.allocator.decref(b)
        self.free_slots.append(st.slot)
        del self._active[st.slot]
        tokens = st.prior + st.out
        results[st.req.rid] = RequestResult(
            rid=st.req.rid, tokens=np.asarray(tokens, np.int32),
            t_admit=st.t_admit, t_first=st.t_first, t_done=now,
            ttft_s=st.t_first - st.req.arrival,
            token_times=np.asarray(st.token_times, np.float64),
            preemptions=st.preemptions, tenant=st.req.tenant)

    def _prefill_one(self, t0: float,
                     results: dict[int, RequestResult]) -> None:
        st = self._prefilling[0]
        plen = len(st.served)
        c = min(self.pool.prefill_chunk, plen - st.filled)
        if self.has_attn:
            c = min(c, st.ring_cap)   # scatter uniqueness within a chunk
        toks = jnp.asarray(st.served[st.filled:st.filled + c],
                           jnp.int32)[None]
        with self._kernel_scope():
            logits, self.caches = self._chunk(
                self.params, self.caches, toks, jnp.int32(st.filled),
                jnp.int32(st.slot), jnp.asarray(st.bt_row),
                jnp.int32(st.ring_cap))
            if self.speculating:
                # the draft arena must hold the prompt too — prefill it in
                # the same chunks (cheap: the draft's packed codes are the
                # low-budget quantization); its logits are unused
                _, self.draft_caches = self._chunk(
                    self.draft_params, self.draft_caches, toks,
                    jnp.int32(st.filled), jnp.int32(st.slot),
                    jnp.asarray(st.bt_row), jnp.int32(st.ring_cap))
        st.filled += c
        if self.speculating:
            st.draft_pos = st.filled
        self.stats["prefill_chunks"] = self.stats.get("prefill_chunks", 0) + 1
        self.stats["prefill_tokens"] = self.stats.get("prefill_tokens", 0) + c
        if self.prefix_cache is not None:
            # blocks completed by this chunk are fully written: publish them
            # so concurrent requests sharing the prompt hit them immediately
            self._register_blocks(st, st.served, st.filled)
        if st.filled == plen:
            self._prefilling.popleft()
            tok = self._sample(np.asarray(logits[0]), st.req.rid, st.n_done)
            now = time.monotonic() - t0       # after the step has completed
            self._emit(st, tok, now)
            if st.n_done >= st.req.max_new or tok == st.req.eos:
                self._active[st.slot] = st   # _finish expects it registered
                self._finish(st, now, results)
            else:
                self._active[st.slot] = st

    def _decode_once(self, t0: float,
                     results: dict[int, RequestResult]) -> None:
        s = self.pool.max_slots
        tokens = np.zeros((s, 1), np.int32)
        pos = np.zeros(s, np.int32)
        active = np.zeros(s, bool)
        bts = np.zeros((s, self.table_width), np.int32)
        ring = np.ones(s, np.int32)
        for slot, st in self._active.items():
            tokens[slot, 0] = st.out[-1]
            pos[slot] = len(st.served) + len(st.out) - 1
            active[slot] = True
            bts[slot] = st.bt_row
            ring[slot] = st.ring_cap
        with self._kernel_scope():
            logits, self.caches = self._step(
                self.params, self.caches, jnp.asarray(tokens),
                jnp.asarray(pos), jnp.asarray(active), jnp.asarray(bts),
                jnp.asarray(ring))
        logits = np.asarray(logits)
        now = time.monotonic() - t0           # after the step has completed
        self.stats["decode_steps"] = self.stats.get("decode_steps", 0) + 1
        self.stats.setdefault("occupancy", []).append(
            len(self._active) / self.pool.max_slots)
        for slot in list(self._active):
            st = self._active[slot]
            tok = self._sample(logits[slot], st.req.rid, st.n_done)
            self._emit(st, tok, now)
            if st.n_done >= st.req.max_new or tok == st.req.eos:
                self._finish(st, now, results)

    # ---------------------------------------------------------- speculation

    def _spec_decode_once(self, t0: float,
                          results: dict[int, RequestResult]) -> None:
        """One draft-propose / target-verify round over the whole slot set.

        Draft phase: a fixed-shape 2-token catch-up step (feeds the tokens
        at positions pos-1 and pos; the first position's arena write is
        masked unless that slot has a post-all-accept hole) followed by k-1
        single-token draft steps, yielding k proposals per slot and the
        draft logits each was sampled from.  Verify phase: the target
        scores [last, d_1..d_k] at positions pos..pos+k in one batched
        ``decode_verify_paged`` dispatch.  Acceptance runs host-side per
        slot (``speculative_accept``), emitting 1..k+1 tokens per round.
        """
        s, k = self.pool.max_slots, self.speculate
        catch = np.zeros((s, 2), np.int32)    # tokens at pos-1, pos
        pos = np.zeros(s, np.int32)
        active = np.zeros(s, bool)
        hole = np.zeros(s, bool)
        bts = np.zeros((s, self.table_width), np.int32)
        ring = np.ones(s, np.int32)
        for slot, st in self._active.items():
            p = len(st.served) + len(st.out) - 1
            pos[slot] = p
            catch[slot, 0] = (st.out[-2] if len(st.out) >= 2
                              else st.served[-1])
            catch[slot, 1] = st.out[-1]
            active[slot] = True
            # after an all-accept round the bonus token's predecessor (d_k)
            # was never fed to the draft: position p-1 is a hole the
            # catch-up step must commit; otherwise the rewrite is masked so
            # shared prefix-cache blocks are never touched
            hole[slot] = st.draft_pos == p - 1
            bts[slot] = st.bt_row
            ring[slot] = st.ring_cap
        wmask = np.ones((s, 2), bool)
        wmask[:, 0] = hole
        with self._kernel_scope():
            dlog, self.draft_caches = self._catchup(
                self.draft_params, self.draft_caches, jnp.asarray(catch),
                jnp.asarray(pos - 1), jnp.asarray(active), jnp.asarray(bts),
                jnp.asarray(ring), jnp.asarray(wmask))
        dl = np.asarray(dlog[:, 1])           # draft logits at position pos
        draft_logits = np.zeros((s, k) + dl.shape[1:], np.float32)
        draft_tokens = np.zeros((s, k), np.int32)
        toks = np.zeros((s, 1), np.int32)
        for i in range(k):
            draft_logits[:, i] = dl
            for slot, st in self._active.items():
                d = self._draft_sample(dl[slot], st.req.rid, st.n_done, i)
                draft_tokens[slot, i] = d
                toks[slot, 0] = d
            if i < k - 1:
                with self._kernel_scope():
                    nxt, self.draft_caches = self._draft_step(
                        self.draft_params, self.draft_caches,
                        jnp.asarray(toks), jnp.asarray(pos + 1 + i),
                        jnp.asarray(active), jnp.asarray(bts),
                        jnp.asarray(ring))
                dl = np.asarray(nxt)
        verify_toks = np.concatenate([catch[:, 1:2], draft_tokens], axis=1)
        with self._kernel_scope():
            tlog, self.caches = self._verify(
                self.params, self.caches, jnp.asarray(verify_toks),
                jnp.asarray(pos), jnp.asarray(active), jnp.asarray(bts),
                jnp.asarray(ring), jnp.ones((s, k + 1), bool))
        tlog = np.asarray(tlog)
        now = time.monotonic() - t0           # after the step has completed
        self.stats["spec_rounds"] = self.stats.get("spec_rounds", 0) + 1
        self.stats.setdefault("occupancy", []).append(
            len(self._active) / self.pool.max_slots)
        for slot in list(self._active):
            st = self._active[slot]
            # greedy needs no RNG (and warmup requests may carry negative
            # rids, which SeedSequence rejects)
            rng = (np.random.default_rng(
                       (self.seed, st.req.rid, st.n_done, 7))
                   if self.temperature > 0.0 else None)
            emitted, n_acc = speculative_accept(
                tlog[slot], draft_logits[slot], draft_tokens[slot],
                self.temperature, rng)
            self.stats["spec_proposed"] = (
                self.stats.get("spec_proposed", 0) + k)
            self.stats["spec_accepted"] = (
                self.stats.get("spec_accepted", 0) + n_acc)
            p = int(pos[slot])
            # draft KV is valid through the last accepted draft position
            # (the replacement/bonus token is never fed to the draft)
            st.draft_pos = min(p + n_acc + 1, p + k)
            for tok in emitted:
                self._emit(st, tok, now)
                if (st.n_done >= st.req.max_new or tok == st.req.eos):
                    break
            if st.n_done >= st.req.max_new or st.out[-1] == st.req.eos:
                self._finish(st, now, results)

    # --------------------------------------------------------- preemption

    def _evict_inflight(self, rid: int) -> Optional[_InFlight]:
        """Pull request ``rid`` out of the prefill/decode sets: register
        its fully-written blocks in the prefix cache (so they park on the
        allocator's LRU with their KV intact rather than being recomputed
        from scratch later), release its block refs, and free its slot.
        Returns the removed state, or None if ``rid`` is not in flight."""
        st = next((s for s in self._active.values() if s.req.rid == rid),
                  None)
        from_active = st is not None
        if st is None:
            st = next((s for s in self._prefilling if s.req.rid == rid),
                      None)
        if st is None:
            return None
        if self.prefix_cache is not None:
            # KV is written through len(served)+len(out)-2 when decoding
            # (the newest sampled token was never fed back); mid-prefill,
            # _prefill_one already registered every completed block.
            if st.out:
                seq = np.concatenate([st.served,
                                      np.asarray(st.out[:-1], np.int32)])
                self._register_blocks(st, seq, len(seq))
        for b in reversed(st.blocks):
            self.allocator.decref(b)
        self.free_slots.append(st.slot)
        if from_active:
            del self._active[st.slot]
        else:
            self._prefilling.remove(st)
        return st

    def preempt(self, rid: int) -> Request | None:
        """Drop-and-replay preemption (DESIGN.md §12): evict request
        ``rid``'s KV blocks and return its ``Request`` so a scheduler can
        requeue it; ``None`` if ``rid`` is not in flight.

        The victim's generated KV blocks are registered in the prefix
        cache before its refs are released, so on a cacheable engine the
        replay's prefill is a warm walk over its own cached history and
        recompute is one chunk, not the whole sequence (unless allocation
        pressure reclaimed the blocks in between).  The replay
        continuation (already-emitted tokens, first-incarnation
        timestamps) is held internally by rid and picked up when the same
        rid is resubmitted; emitted tokens are re-fed as extra prompt, so
        a preempted-then-replayed greedy request is token-identical to an
        uninterrupted run (pinned in tests/test_frontdoor.py)."""
        st = self._evict_inflight(rid)
        if st is None:
            return None
        self._replay[rid] = _Replay(
            prior=st.prior + st.out, t_admit=st.t_admit, t_first=st.t_first,
            token_times=list(st.token_times),
            preemptions=st.preemptions + 1)
        self.stats["preemptions"] = self.stats.get("preemptions", 0) + 1
        return st.req

    def cancel(self, rid: int) -> bool:
        """Abort request ``rid`` wherever it is — queued, prefilling, or
        decoding — freeing its resources and dropping any replay
        continuation (the front door calls this when a streaming client
        disconnects).  Returns True if anything was removed."""
        for i, r in enumerate(self._pending):
            if r.rid == rid:
                del self._pending[i]
                self._replay.pop(rid, None)
                self.stats["cancelled"] = self.stats.get("cancelled", 0) + 1
                return True
        had_replay = self._replay.pop(rid, None) is not None
        st = self._evict_inflight(rid)
        if st is not None or had_replay:
            self.stats["cancelled"] = self.stats.get("cancelled", 0) + 1
        return st is not None or had_replay

    def inflight(self) -> list:
        """Scheduler's view of every request currently holding (or queued
        for) resources: ``(request, phase, tokens_done, t_admit)`` tuples
        with phase in ``{"pending", "prefill", "decode"}``.  ``prefill`` and
        ``decode`` entries hold a slot and blocks and are preemptible."""
        out = [(r, "pending", 0, r.arrival) for r in self._pending]
        out += [(s.req, "prefill", s.n_done, s.t_admit)
                for s in self._prefilling]
        out += [(s.req, "decode", s.n_done, s.t_admit)
                for s in self._active.values()]
        return out

    # ------------------------------------------------------------------ run

    def poll(self) -> bool:
        """Whether the engine has outstanding work (queued, prefilling, or
        decoding requests).  Preempted-but-not-yet-resubmitted requests are
        the *scheduler's* outstanding work, not the engine's."""
        return bool(self._pending or self._prefilling or self._active)

    @property
    def active_count(self) -> int:
        """Requests currently decoding (the population an SLO protects)."""
        return len(self._active)

    def step(self, *, prefill: bool = True
             ) -> dict[int, RequestResult]:
        """ONE re-entrant scheduler iteration: admit due requests, run one
        prompt chunk (unless ``prefill=False`` — the SLO controller's
        chunked-prefill throttle), then one decode step over the slot set.
        Returns the requests that finished during this call (streaming
        consumers also saw their tokens via ``on_token``).  ``run`` is a
        drain loop over this; a front door calls it forever."""
        results: dict[int, RequestResult] = {}
        self.start_clock()
        self._try_admit(self.now())
        if prefill and self._prefilling:
            self._prefill_one(self._t0, results)
        if self._active:
            if self.speculate:
                self._spec_decode_once(self._t0, results)
            else:
                self._decode_once(self._t0, results)
            end = time.monotonic()
            if self._last_decode_end is not None:
                gap = end - self._last_decode_end
                self.decode_gaps.append(gap)
                self.stats.setdefault("decode_gap_s", []).append(gap)
            self._last_decode_end = end
        else:
            # no decode ran: the next gap would measure idleness, not
            # scheduling interference — restart the gap baseline
            self._last_decode_end = None
        return results

    def finalize_stats(self) -> dict:
        """Fold the per-step counters into the summary numbers (mean
        occupancy, acceptance rate, prefix hit rate); returns ``stats``."""
        occ = self.stats.get("occupancy", [])
        self.stats["mean_occupancy"] = float(np.mean(occ)) if occ else 0.0
        if self.speculate:
            prop = self.stats.get("spec_proposed", 0)
            self.stats["acceptance_rate"] = (
                self.stats.get("spec_accepted", 0) / prop if prop else 0.0)
        if self.prefix_cache is not None:
            pt = self.stats.get("prompt_tokens", 0)
            self.stats["prefix_hit_rate"] = (
                self.stats.get("prefill_tokens_saved", 0) / pt if pt else 0.0)
            self.stats["prefix_evictions"] = self.prefix_cache.evictions
            self.stats["prefix_cached_blocks"] = len(self.prefix_cache)
        return self.stats

    def run(self, requests: list[Request] | None = None
            ) -> dict[int, RequestResult]:
        """Serve until every submitted request completes.  Returns
        rid -> RequestResult; aggregate stats land in ``self.stats``
        (occupancy, prefill/prefix counters, and — when speculating —
        spec_rounds / spec_proposed / spec_accepted / acceptance_rate)."""
        for r in requests or []:
            self.submit(r)
        self._pending = collections.deque(
            sorted(self._pending, key=lambda r: r.arrival))
        results: dict[int, RequestResult] = {}
        self._t0 = time.monotonic()       # each run() restarts the clock
        self._last_decode_end = None
        while self.poll():
            results.update(self.step())
            if not self._active and not self._prefilling and self._pending:
                wait = self._pending[0].arrival - self.now()
                if wait > 0:
                    time.sleep(min(wait, 0.05))
        self.finalize_stats()
        return results
