"""Continuous-batching LM serving engine over the paged KV-cache pool.

Scheduler loop (one iteration): admit queued requests while slots and blocks
are free, run ONE prompt chunk for the oldest mid-prefill request, then run
ONE decode step over the whole slot set.  Chunked prefill therefore
interleaves with decode instead of stalling it, and a request that hits EOS
or its token budget frees its slot and blocks immediately, so queued
requests join mid-flight — nobody waits for a batch to drain (the lockstep
failure mode ``launch/serve.BatchedServer`` keeps around as the A/B
baseline).

The decode step is jitted ONCE per engine: batch-composition churn only
changes the *contents* of (tokens, pos, active, block_tables, ring_cap)
arrays, never their shapes, so quantized weights stay resident and decode
occupancy is limited by traffic, not recompilation
(``decode_trace_count`` is asserted == 1 in tests/test_paged_engine.py).

Admission consults the content-addressed prefix cache (DESIGN.md §8): the
longest cached prefix of the prompt is served straight from the pool
(refcounts bumped, chunked prefill starts at the first uncached token, a
mid-block match is cloned copy-on-write), and completed requests *release*
their blocks — fully-written blocks stay cached on an LRU that is evicted
only under allocation pressure.  Pure-attention, non-windowed archs only;
ring-window blocks mutate in place and recurrent/MLA state is per-slot, so
those configs bypass the cache entirely.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.qmatmul import ops as qops
from repro.models import decode as decmod
from repro.models.config import ModelConfig

from .pool import (BlockAllocator, PoolConfig, PrefixCache, init_pool_caches,
                   request_blocks)


@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival`` is seconds after run start
    (workload simulation); the engine will not admit it earlier."""
    rid: int
    prompt: np.ndarray               # (plen,) int32
    max_new: int
    eos: Optional[int] = None
    arrival: float = 0.0


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray               # generated tokens (<= max_new)
    t_admit: float                   # seconds after run start
    t_first: float                   # first generated token
    t_done: float


@dataclasses.dataclass
class _InFlight:
    req: Request
    slot: int
    blocks: list
    bt_row: np.ndarray               # (MB,) int32 physical block ids
    ring_cap: int                    # tokens; ring for windowed archs
    filled: int = 0                  # prompt tokens prefilled so far
    out: list = dataclasses.field(default_factory=list)
    t_admit: float = 0.0
    t_first: float = 0.0
    chain: object = None             # prefix-cache hash of last full block
    n_hashed: int = 0                # full blocks matched/registered so far


class PagedServer:
    """Continuous-batching engine; greedy or temperature sampling.

    ``fused`` selects the RHT+qmatmul fusion for every traced function of
    this engine via the scoped ``qops.fusion`` context (fixed per engine —
    the jitted step is traced under it exactly once).
    """

    def __init__(self, cfg: ModelConfig, params: dict,
                 pool: PoolConfig | None = None, *, fused: bool = True,
                 temperature: float = 0.0, seed: int = 0):
        if cfg.enc_dec:
            raise ValueError(
                "PagedServer does not support encoder-decoder archs")
        self.cfg = cfg
        self.params = params
        self.pool = pool or PoolConfig()
        self.fused = fused
        self.temperature = temperature
        self.seed = seed
        self.caches = init_pool_caches(cfg, params, self.pool)
        # Prefix caching needs blocks that are immutable once written:
        # pure-attention archs without a sliding window.  Windowed archs
        # ring-reuse their blocks in place, and recurrent/MLA state lives in
        # per-slot arrays the cache can't name — both bypass.
        self.cacheable = (self.pool.prefix_cache and cfg.window is None
                          and all(mx == "attn" for mx in cfg.pattern))
        self.prefix_cache = (PrefixCache(self.pool.block_size)
                             if self.cacheable else None)
        self.allocator = BlockAllocator(self.pool.resolved_num_blocks(cfg),
                                        cache=self.prefix_cache)
        self.free_slots = list(range(self.pool.max_slots - 1, -1, -1))
        self.table_width = max(
            request_blocks(cfg, self.pool, self.pool.max_context), 1)
        self.has_attn = "attn" in cfg.pattern
        self.decode_trace_count = 0
        self.stats: dict = {}
        self._pending: collections.deque[Request] = collections.deque()
        self._prefilling: collections.deque[_InFlight] = collections.deque()
        self._active: dict[int, _InFlight] = {}

        # Caches are donated: the pool buffers alias input->output instead of
        # being copied every step (same pattern as launch/dryrun.py).  jit's
        # own shape cache handles the few distinct prefill chunk lengths.
        def _step(params_, caches, tokens, pos, active, bts, ring):
            self.decode_trace_count += 1      # trace-time side effect only
            return decmod.decode_step_paged(cfg, params_, caches, tokens,
                                            pos, active, bts, ring)

        def _chunk(params_, caches, toks, pos0, slot, bt, ring):
            return decmod.prefill_chunk_paged(cfg, params_, caches, toks,
                                              pos0, slot, bt, ring)

        def _cow(caches, src, dst):
            # clone one physical block's KV across every layer arena
            return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), caches)

        self._step = jax.jit(_step, donate_argnums=(1,))
        self._chunk = jax.jit(_chunk, donate_argnums=(1,))
        self._cow = jax.jit(_cow, donate_argnums=(0,))

    # ------------------------------------------------------------- plumbing

    def _sample(self, logits: np.ndarray, rid: int, step: int) -> int:
        if self.temperature <= 0.0:
            return int(np.argmax(logits))
        rng = np.random.default_rng((self.seed, rid, step))
        g = rng.gumbel(size=logits.shape)
        return int(np.argmax(logits / self.temperature + g))

    # ------------------------------------------------------------ lifecycle

    def submit(self, req: Request) -> None:
        if len(req.prompt) < 1 or req.max_new < 1:
            raise ValueError(
                f"request {req.rid}: needs a non-empty prompt and "
                f"max_new >= 1 (got {len(req.prompt)}, {req.max_new})")
        total = len(req.prompt) + req.max_new
        if total > self.pool.max_context:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = {total} exceeds "
                f"max_context = {self.pool.max_context}")
        need = request_blocks(self.cfg, self.pool, total)
        if need > self.allocator.num_blocks - 1:
            raise ValueError(
                f"request {req.rid}: needs {need} blocks, pool has "
                f"{self.allocator.num_blocks - 1}")
        self._pending.append(req)

    def _try_admit(self, now: float) -> None:
        # FIFO with head-of-line blocking: admission control is purely
        # "do I have a slot and enough blocks for this request's capacity".
        while self._pending and self._pending[0].arrival <= now:
            req = self._pending[0]
            if not self.free_slots:
                return
            total = len(req.prompt) + req.max_new
            need = request_blocks(self.cfg, self.pool, total)
            # Longest cached prefix: whole-block hits are shared (refcount
            # bumped before alloc so allocation pressure can't evict them);
            # a mid-block match is cloned copy-on-write into the request's
            # first private block.  Capped at plen - 1: at least one prompt
            # token is always recomputed to produce first-token logits.
            hits: list[int] = []
            parent, cached, cow_src = None, 0, None
            if self.prefix_cache is not None:
                hits, parent, cached, cow_src = self.prefix_cache.match(
                    req.prompt, len(req.prompt) - 1)
                for b in hits:
                    self.allocator.incref(b)
                if cow_src is not None:
                    self.allocator.incref(cow_src)
            fresh = self.allocator.alloc(need - len(hits))
            if fresh is None:
                if cow_src is not None:
                    self.allocator.decref(cow_src)
                for b in reversed(hits):      # leaf-first, like _finish
                    self.allocator.decref(b)
                return
            if cow_src is not None:
                # fresh[0] sits at logical index len(hits) — exactly where
                # the partially-matching block's contents belong
                self.caches = self._cow(self.caches, jnp.int32(cow_src),
                                        jnp.int32(fresh[0]))
                self.allocator.decref(cow_src)
                self.stats["prefix_cow"] = self.stats.get("prefix_cow", 0) + 1
            blocks = hits + fresh
            self._pending.popleft()
            slot = self.free_slots.pop()
            bt_row = np.zeros(self.table_width, np.int32)
            bt_row[:need] = blocks
            ring_cap = len(blocks) * self.pool.block_size if blocks else 1
            if self.prefix_cache is not None:
                self.stats["prompt_tokens"] = (
                    self.stats.get("prompt_tokens", 0) + len(req.prompt))
                self.stats["prefill_tokens_saved"] = (
                    self.stats.get("prefill_tokens_saved", 0) + cached)
                if cached:
                    self.stats["prefix_hits"] = (
                        self.stats.get("prefix_hits", 0) + 1)
            self._prefilling.append(_InFlight(
                req=req, slot=slot, blocks=blocks, bt_row=bt_row,
                ring_cap=ring_cap, filled=cached, t_admit=now,
                chain=parent, n_hashed=len(hits)))

    def _register_blocks(self, st: _InFlight, seq, upto: int) -> None:
        """Register st's fully-written blocks covering positions < upto
        (KV for those positions is in the arena) into the prefix cache."""
        bs = self.pool.block_size
        while (st.n_hashed + 1) * bs <= upto:
            k = st.n_hashed
            st.chain = self.prefix_cache.register(
                st.chain, seq[k * bs:(k + 1) * bs], int(st.bt_row[k]))
            st.n_hashed += 1

    def _finish(self, st: _InFlight, now: float,
                results: dict[int, RequestResult]) -> None:
        if self.prefix_cache is not None:
            # decode wrote KV through position plen + len(out) - 2 (the last
            # sampled token was never fed back), so generated tokens extend
            # the cached chain too — multi-turn prompts hit their history
            seq = np.concatenate([st.req.prompt,
                                  np.asarray(st.out[:-1], np.int32)])
            self._register_blocks(st, seq, len(seq))
        # children (later blocks) enter the idle LRU first, so eviction
        # under pressure reclaims leaves before the prefixes they chain off
        for b in reversed(st.blocks):
            self.allocator.decref(b)
        self.free_slots.append(st.slot)
        del self._active[st.slot]
        results[st.req.rid] = RequestResult(
            rid=st.req.rid, tokens=np.asarray(st.out, np.int32),
            t_admit=st.t_admit, t_first=st.t_first, t_done=now)

    def _prefill_one(self, t0: float,
                     results: dict[int, RequestResult]) -> None:
        st = self._prefilling[0]
        plen = len(st.req.prompt)
        c = min(self.pool.prefill_chunk, plen - st.filled)
        if self.has_attn:
            c = min(c, st.ring_cap)   # scatter uniqueness within a chunk
        toks = jnp.asarray(st.req.prompt[st.filled:st.filled + c],
                           jnp.int32)[None]
        with qops.fusion(self.fused):
            logits, self.caches = self._chunk(
                self.params, self.caches, toks, jnp.int32(st.filled),
                jnp.int32(st.slot), jnp.asarray(st.bt_row),
                jnp.int32(st.ring_cap))
        st.filled += c
        self.stats["prefill_chunks"] = self.stats.get("prefill_chunks", 0) + 1
        self.stats["prefill_tokens"] = self.stats.get("prefill_tokens", 0) + c
        if self.prefix_cache is not None:
            # blocks completed by this chunk are fully written: publish them
            # so concurrent requests sharing the prompt hit them immediately
            self._register_blocks(st, st.req.prompt, st.filled)
        if st.filled == plen:
            self._prefilling.popleft()
            tok = self._sample(np.asarray(logits[0]), st.req.rid, 0)
            now = time.monotonic() - t0       # after the step has completed
            st.out.append(tok)
            st.t_first = now
            if len(st.out) >= st.req.max_new or tok == st.req.eos:
                self._active[st.slot] = st   # _finish expects it registered
                self._finish(st, now, results)
            else:
                self._active[st.slot] = st

    def _decode_once(self, t0: float,
                     results: dict[int, RequestResult]) -> None:
        s = self.pool.max_slots
        tokens = np.zeros((s, 1), np.int32)
        pos = np.zeros(s, np.int32)
        active = np.zeros(s, bool)
        bts = np.zeros((s, self.table_width), np.int32)
        ring = np.ones(s, np.int32)
        for slot, st in self._active.items():
            tokens[slot, 0] = st.out[-1]
            pos[slot] = len(st.req.prompt) + len(st.out) - 1
            active[slot] = True
            bts[slot] = st.bt_row
            ring[slot] = st.ring_cap
        with qops.fusion(self.fused):
            logits, self.caches = self._step(
                self.params, self.caches, jnp.asarray(tokens),
                jnp.asarray(pos), jnp.asarray(active), jnp.asarray(bts),
                jnp.asarray(ring))
        logits = np.asarray(logits)
        now = time.monotonic() - t0           # after the step has completed
        self.stats["decode_steps"] = self.stats.get("decode_steps", 0) + 1
        self.stats.setdefault("occupancy", []).append(
            len(self._active) / self.pool.max_slots)
        for slot in list(self._active):
            st = self._active[slot]
            tok = self._sample(logits[slot], st.req.rid, len(st.out))
            st.out.append(tok)
            if len(st.out) >= st.req.max_new or tok == st.req.eos:
                self._finish(st, now, results)

    # ------------------------------------------------------------------ run

    def run(self, requests: list[Request] | None = None
            ) -> dict[int, RequestResult]:
        """Serve until every submitted request completes.  Returns
        rid -> RequestResult; aggregate stats land in ``self.stats``."""
        for r in requests or []:
            self.submit(r)
        self._pending = collections.deque(
            sorted(self._pending, key=lambda r: r.arrival))
        results: dict[int, RequestResult] = {}
        t0 = time.monotonic()
        while self._pending or self._prefilling or self._active:
            self._try_admit(time.monotonic() - t0)
            if self._prefilling:
                self._prefill_one(t0, results)
            if self._active:
                self._decode_once(t0, results)
            elif not self._prefilling:
                if self._pending:
                    wait = self._pending[0].arrival - (time.monotonic() - t0)
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
        occ = self.stats.get("occupancy", [])
        self.stats["mean_occupancy"] = float(np.mean(occ)) if occ else 0.0
        if self.prefix_cache is not None:
            pt = self.stats.get("prompt_tokens", 0)
            self.stats["prefix_hit_rate"] = (
                self.stats.get("prefill_tokens_saved", 0) / pt if pt else 0.0)
            self.stats["prefix_evictions"] = self.prefix_cache.evictions
            self.stats["prefix_cached_blocks"] = len(self.prefix_cache)
        return results
