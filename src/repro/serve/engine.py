"""Continuous-batching LM serving engine over the paged KV-cache pool.

Scheduler loop (one iteration): admit queued requests while slots and blocks
are free, run ONE prompt chunk for the oldest mid-prefill request, then run
ONE decode step over the whole slot set.  Chunked prefill therefore
interleaves with decode instead of stalling it, and a request that hits EOS
or its token budget frees its slot and blocks immediately, so queued
requests join mid-flight — nobody waits for a batch to drain (the lockstep
failure mode ``launch/serve.BatchedServer`` keeps around as the A/B
baseline).

The decode step is jitted ONCE per engine: batch-composition churn only
changes the *contents* of (tokens, pos, active, block_tables, ring_cap)
arrays, never their shapes, so quantized weights stay resident and decode
occupancy is limited by traffic, not recompilation
(``decode_trace_count`` is asserted == 1 in tests/test_paged_engine.py).

Admission consults the content-addressed prefix cache (DESIGN.md §8): the
longest cached prefix of the prompt is served straight from the pool
(refcounts bumped, chunked prefill starts at the first uncached token, a
mid-block match is cloned copy-on-write), and completed requests *release*
their blocks — fully-written blocks stay cached on an LRU that is evicted
only under allocation pressure.  Pure-attention, non-windowed archs only;
ring-window blocks mutate in place and recurrent/MLA state is per-slot, so
those configs bypass the cache entirely.

Self-speculative decoding (DESIGN.md §9): with ``draft_params`` (a second,
aggressively low-bit quantization of the SAME weights — see
``core.pipeline.quantize_model_dual``) and ``speculate=k``, the decode
phase becomes draft-propose / target-verify: the draft decodes k tokens
autoregressively through its own KV arena (same block tables as the
target's, so prefix hits warm both), the target scores all k+1 positions in
one batched ``decode_verify_paged`` step, and the standard rejection-
sampling acceptance rule emits between 1 and k+1 tokens per round while
preserving the target distribution exactly (greedy mode is token-identical
to non-speculative decoding).  Attention archs only; recurrent/MLA archs
bypass speculation because their sequential per-slot state cannot absorb
rejected positions.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention import ops as pops
from repro.kernels.qmatmul import ops as qops
from repro.models import decode as decmod
from repro.models.config import ModelConfig
from repro.runtime import tp as tpmod

from .pool import (BlockAllocator, PoolConfig, PrefixCache, init_pool_caches,
                   request_blocks)


@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival`` is seconds after run start
    (workload simulation); the engine will not admit it earlier."""
    rid: int
    prompt: np.ndarray               # (plen,) int32
    max_new: int
    eos: Optional[int] = None
    arrival: float = 0.0


@dataclasses.dataclass
class RequestResult:
    """Completion record for one request: the generated tokens plus the
    admission / first-token / completion timestamps (seconds after run
    start) the serving benchmarks turn into latency percentiles."""
    rid: int
    tokens: np.ndarray               # generated tokens (<= max_new)
    t_admit: float                   # seconds after run start
    t_first: float                   # first generated token
    t_done: float


@dataclasses.dataclass
class _InFlight:
    req: Request
    slot: int
    blocks: list
    bt_row: np.ndarray               # (MB,) int32 physical block ids
    ring_cap: int                    # tokens; ring for windowed archs
    filled: int = 0                  # prompt tokens prefilled so far
    out: list = dataclasses.field(default_factory=list)
    t_admit: float = 0.0
    t_first: float = 0.0
    chain: object = None             # prefix-cache hash of last full block
    n_hashed: int = 0                # full blocks matched/registered so far
    draft_pos: int = 0               # draft-KV-valid positions (speculation)


def speculative_accept(target_logits: np.ndarray, draft_logits: np.ndarray,
                       draft_tokens: np.ndarray, temperature: float,
                       rng: np.random.Generator):
    """Standard speculative-sampling acceptance rule for one slot's round.

    ``target_logits`` (k+1, V) are the target model's logits at the k+1
    verified positions (last accepted token + k draft tokens);
    ``draft_logits`` (k, V) are the logits each ``draft_tokens[i]`` was
    sampled from.  Greedy (``temperature <= 0``): accept ``d_i`` while it
    equals the target argmax at its position, emit the target argmax at the
    first mismatch, emit the bonus argmax after a full accept — every
    emitted token is a target argmax, so greedy speculation is
    token-identical to non-speculative decoding.  Sampling
    (``temperature > 0``): accept ``d_i`` with probability
    ``min(1, p_t(d_i) / p_d(d_i))``, on rejection sample from the residual
    ``normalize(max(p_t - p_d, 0))``, after a full accept sample the bonus
    from the target's last distribution — the marginal distribution of
    emitted tokens equals target-only sampling (Leviathan et al., 2023;
    pinned statistically in tests/test_speculative.py).  Returns
    ``(tokens, n_accepted)`` with ``len(tokens) == n_accepted + 1``.
    """
    k = len(draft_tokens)
    out: list[int] = []
    if temperature <= 0.0:
        for i in range(k):
            t_star = int(np.argmax(target_logits[i]))
            out.append(t_star)
            if int(draft_tokens[i]) != t_star:
                return out, i
        out.append(int(np.argmax(target_logits[k])))
        return out, k

    def dist(logits):
        z = logits.astype(np.float64) / temperature
        e = np.exp(z - z.max())
        return e / e.sum()

    for i in range(k):
        p_t, p_d = dist(target_logits[i]), dist(draft_logits[i])
        d = int(draft_tokens[i])
        if rng.random() < min(1.0, p_t[d] / max(p_d[d], 1e-300)):
            out.append(d)
            continue
        resid = np.maximum(p_t - p_d, 0.0)
        s = resid.sum()
        p = resid / s if s > 0.0 else p_t
        out.append(int(rng.choice(p.size, p=p)))
        return out, i
    p_t = dist(target_logits[k])
    out.append(int(rng.choice(p_t.size, p=p_t)))
    return out, k


class PagedServer:
    """Continuous-batching engine over the paged KV pool; greedy or
    temperature sampling.

    ``fused`` selects the RHT+qmatmul fusion for every traced function of
    this engine via the scoped ``qops.fusion`` context (fixed per engine —
    each jitted step is traced under it exactly once).  ``paged_kernel``
    likewise pins the attention read: True routes every paged attention
    (decode / catch-up / verify) through the Pallas flash-decode kernel
    over the block arena (interpret-mode off TPU), False through the dense
    gather reference, and None (default) lets the backend decide — kernel
    on TPU, gather elsewhere (DESIGN.md §10).  ``draft_params`` +
    ``speculate=k`` turn on self-speculative decoding (draft proposes k
    tokens, target verifies them in one batched step; see the module
    docstring and DESIGN.md §9); recurrent/MLA archs silently bypass
    speculation and run the plain decode loop.  Construct once per (model,
    PoolConfig) — all serving state (arenas, allocator, queues, stats)
    lives on the instance, and ``run`` drains a workload to completion.

    ``mesh`` (a ``("data", "model")`` mesh, e.g. from
    ``launch.mesh.make_host_mesh(tp=2)``) turns on tensor-parallel serving
    (DESIGN.md §11): params are column-shard-placed per ``runtime.tp``'s
    plan, the KV block arenas shard their head axis, and every jitted step
    runs inside one ``shard_map`` over the mesh.  Default is the trivial
    (1, 1) mesh — single-device serving is the TP=1 special case of the
    same code path, not a separate one.  Scheduler/allocator/prefix-cache
    state stays host-side and replicated regardless of TP degree.
    """

    def __init__(self, cfg: ModelConfig, params: dict,
                 pool: PoolConfig | None = None, *, fused: bool = True,
                 paged_kernel: bool | None = None,
                 temperature: float = 0.0, seed: int = 0,
                 draft_params: dict | None = None, speculate: int = 0,
                 mesh=None):
        if cfg.enc_dec:
            raise ValueError(
                "PagedServer does not support encoder-decoder archs")
        if speculate < 0:
            raise ValueError(f"speculate must be >= 0 (got {speculate})")
        if speculate and draft_params is None:
            raise ValueError("speculate > 0 requires draft_params "
                             "(see core.pipeline.quantize_model_dual)")
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else tpmod.default_mesh()
        self.tp = int(self.mesh.shape[tpmod.AXIS])
        self.tp_plan = tpmod.plan_for(cfg, self.tp)
        self.params, self._pspecs = tpmod.prepare_params(cfg, params,
                                                         self.mesh)
        self.pool = pool or PoolConfig()
        self.fused = fused
        self.paged_kernel = paged_kernel
        self.temperature = temperature
        self.seed = seed
        # Speculation needs KV that is addressable by absolute position so
        # rejected tokens can simply be overwritten; sequential per-slot
        # state (RWKV/RG-LRU/MLA latents) cannot roll back, so those archs
        # bypass and serve through the plain decode loop (DESIGN.md §9).
        self.speculating = bool(speculate) and all(
            mx == "attn" for mx in cfg.pattern)
        self.speculate = speculate if self.speculating else 0
        if self.speculating:
            self.draft_params, self._draft_pspecs = tpmod.prepare_params(
                cfg, draft_params, self.mesh)
        else:
            self.draft_params, self._draft_pspecs = None, None
        if self.speculating and self.pool.lookahead < speculate:
            # verify/draft steps write up to `speculate` positions past the
            # accepted frontier; reserve ring capacity so those writes can
            # never wrap onto live history (window or prompt)
            self.pool = dataclasses.replace(self.pool, lookahead=speculate)
        # KV arenas shard their head axis when the plan shards attention;
        # recurrent/MLA slot state replicates (runtime/tp.py).
        self.caches = init_pool_caches(cfg, params, self.pool)
        self._cspecs = tpmod.cache_spec_list(self.caches, self.mesh,
                                             self.tp_plan)
        self.caches = tpmod.place(self.caches, self._cspecs, self.mesh)
        if self.speculating:
            dc = init_pool_caches(cfg, draft_params, self.pool)
            self.draft_caches = tpmod.place(dc, self._cspecs, self.mesh)
        else:
            self.draft_caches = None
        # Prefix caching needs blocks that are immutable once written:
        # pure-attention archs without a sliding window.  Windowed archs
        # ring-reuse their blocks in place, and recurrent/MLA state lives in
        # per-slot arrays the cache can't name — both bypass.
        self.cacheable = (self.pool.prefix_cache and cfg.window is None
                          and all(mx == "attn" for mx in cfg.pattern))
        self.prefix_cache = (PrefixCache(self.pool.block_size)
                             if self.cacheable else None)
        self.allocator = BlockAllocator(self.pool.resolved_num_blocks(cfg),
                                        cache=self.prefix_cache)
        self.free_slots = list(range(self.pool.max_slots - 1, -1, -1))
        self.table_width = max(
            request_blocks(cfg, self.pool, self.pool.max_context), 1)
        self.has_attn = "attn" in cfg.pattern
        self.decode_trace_count = 0
        self.draft_trace_count = 0        # single-token draft steps
        self.catchup_trace_count = 0      # 2-token draft catch-up steps
        self.verify_trace_count = 0       # (k+1)-token target verify steps
        self.stats: dict = {}
        self._pending: collections.deque[Request] = collections.deque()
        self._prefilling: collections.deque[_InFlight] = collections.deque()
        self._active: dict[int, _InFlight] = {}

        # Caches are donated: the pool buffers alias input->output instead of
        # being copied every step (same pattern as launch/dryrun.py).  jit's
        # own shape cache handles the few distinct prefill chunk lengths.
        # Every step runs inside ONE shard_map over the engine mesh
        # (runtime/tp.sharded_call): params/caches enter under their
        # placement specs, step arguments and logits replicate, and cache
        # in/out specs match so donation survives the wrapper.  The draft
        # steps get their own wrappers because the draft quantization has
        # its own param spec list.
        def _wrap(core, pspecs):
            return tpmod.sharded_call(core, self.mesh, pspecs, self._cspecs)

        step_core = _wrap(
            lambda p_, c_, *a: decmod.decode_step_paged(cfg, p_, c_, *a),
            self._pspecs)
        chunk_core = _wrap(
            lambda p_, c_, *a: decmod.prefill_chunk_paged(cfg, p_, c_, *a),
            self._pspecs)
        verify_core = _wrap(
            lambda p_, c_, *a: decmod.decode_verify_paged(cfg, p_, c_, *a),
            self._pspecs)
        if self.speculating:
            draft_step_core = _wrap(
                lambda p_, c_, *a: decmod.decode_step_paged(cfg, p_, c_, *a),
                self._draft_pspecs)
            draft_verify_core = _wrap(
                lambda p_, c_, *a: decmod.decode_verify_paged(cfg, p_, c_,
                                                              *a),
                self._draft_pspecs)

        def _step(params_, caches, tokens, pos, active, bts, ring):
            self.decode_trace_count += 1      # trace-time side effect only
            return step_core(params_, caches, tokens, pos, active, bts, ring)

        def _draft_step(params_, caches, tokens, pos, active, bts, ring):
            self.draft_trace_count += 1       # trace-time side effect only
            return draft_step_core(params_, caches, tokens, pos, active,
                                   bts, ring)

        def _chunk(params_, caches, toks, pos0, slot, bt, ring):
            return chunk_core(params_, caches, toks, pos0, slot, bt, ring)

        def _verify(params_, caches, tokens, pos0, active, bts, ring, wmask):
            self.verify_trace_count += 1      # trace-time side effect only
            return verify_core(params_, caches, tokens, pos0, active, bts,
                               ring, wmask)

        def _catchup(params_, caches, tokens, pos0, active, bts, ring, wmask):
            self.catchup_trace_count += 1     # trace-time side effect only
            return draft_verify_core(params_, caches, tokens, pos0, active,
                                     bts, ring, wmask)

        def _cow_core(caches, src, dst):
            # clone one physical block's KV across every layer arena
            return jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), caches)

        _cow = tpmod.sharded_cache_op(_cow_core, self.mesh, self._cspecs)

        self._step = jax.jit(_step, donate_argnums=(1,))
        self._draft_step = jax.jit(_draft_step, donate_argnums=(1,))
        self._chunk = jax.jit(_chunk, donate_argnums=(1,))
        self._verify = jax.jit(_verify, donate_argnums=(1,))
        self._catchup = jax.jit(_catchup, donate_argnums=(1,))
        self._cow = jax.jit(_cow, donate_argnums=(0,))

    # ------------------------------------------------------------- plumbing

    @contextlib.contextmanager
    def _kernel_scope(self):
        """The engine's fixed kernel selections (RHT+qmatmul fusion, paged
        attention kernel-vs-gather), applied to every traced step — each
        jitted function keeps whatever it was traced under."""
        with qops.fusion(self.fused), pops.paged_kernel(self.paged_kernel):
            yield

    def _sample(self, logits: np.ndarray, rid: int, step: int) -> int:
        """One token from ``logits``: greedy argmax at temperature 0, else
        Gumbel-max sampling with a per-(request, step) deterministic RNG."""
        if self.temperature <= 0.0:
            return int(np.argmax(logits))
        rng = np.random.default_rng((self.seed, rid, step))
        g = rng.gumbel(size=logits.shape)
        return int(np.argmax(logits / self.temperature + g))

    def _draft_sample(self, logits: np.ndarray, rid: int, step: int,
                      i: int) -> int:
        """Draft proposal i of a speculative round: greedy argmax, or a
        sample from softmax(logits / T) — the exact distribution the
        acceptance rule divides by."""
        if self.temperature <= 0.0:
            return int(np.argmax(logits))
        rng = np.random.default_rng((self.seed, rid, step, i, 1))
        z = logits.astype(np.float64) / self.temperature
        e = np.exp(z - z.max())
        return int(rng.choice(e.size, p=e / e.sum()))

    # ------------------------------------------------------------ lifecycle

    def submit(self, req: Request) -> None:
        """Queue a request for admission (it will not start before
        ``req.arrival``).  Validates up front that the request can ever be
        served by this pool — non-empty prompt, at least one generated
        token, and a total footprint (prompt + max_new, plus speculative
        lookahead) that fits ``max_context`` and the block arena."""
        if len(req.prompt) < 1 or req.max_new < 1:
            raise ValueError(
                f"request {req.rid}: needs a non-empty prompt and "
                f"max_new >= 1 (got {len(req.prompt)}, {req.max_new})")
        total = len(req.prompt) + req.max_new
        if total > self.pool.max_context:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = {total} exceeds "
                f"max_context = {self.pool.max_context}")
        need = request_blocks(self.cfg, self.pool, total)
        if need > self.allocator.num_blocks - 1:
            raise ValueError(
                f"request {req.rid}: needs {need} blocks, pool has "
                f"{self.allocator.num_blocks - 1}")
        self._pending.append(req)

    def _try_admit(self, now: float) -> None:
        # FIFO with head-of-line blocking: admission control is purely
        # "do I have a slot and enough blocks for this request's capacity".
        while self._pending and self._pending[0].arrival <= now:
            req = self._pending[0]
            if not self.free_slots:
                return
            total = len(req.prompt) + req.max_new
            need = request_blocks(self.cfg, self.pool, total)
            # Longest cached prefix: whole-block hits are shared (refcount
            # bumped before alloc so allocation pressure can't evict them);
            # a mid-block match is cloned copy-on-write into the request's
            # first private block.  Capped at plen - 1: at least one prompt
            # token is always recomputed to produce first-token logits.
            hits: list[int] = []
            parent, cached, cow_src = None, 0, None
            if self.prefix_cache is not None:
                hits, parent, cached, cow_src = self.prefix_cache.match(
                    req.prompt, len(req.prompt) - 1)
                for b in hits:
                    self.allocator.incref(b)
                if cow_src is not None:
                    self.allocator.incref(cow_src)
            fresh = self.allocator.alloc(need - len(hits))
            if fresh is None:
                if cow_src is not None:
                    self.allocator.decref(cow_src)
                for b in reversed(hits):      # leaf-first, like _finish
                    self.allocator.decref(b)
                return
            if cow_src is not None:
                # fresh[0] sits at logical index len(hits) — exactly where
                # the partially-matching block's contents belong
                self.caches = self._cow(self.caches, jnp.int32(cow_src),
                                        jnp.int32(fresh[0]))
                if self.speculating:
                    # the draft arena shares block tables: clone its copy too
                    self.draft_caches = self._cow(self.draft_caches,
                                                  jnp.int32(cow_src),
                                                  jnp.int32(fresh[0]))
                self.allocator.decref(cow_src)
                self.stats["prefix_cow"] = self.stats.get("prefix_cow", 0) + 1
            blocks = hits + fresh
            self._pending.popleft()
            slot = self.free_slots.pop()
            bt_row = np.zeros(self.table_width, np.int32)
            bt_row[:need] = blocks
            ring_cap = len(blocks) * self.pool.block_size if blocks else 1
            if self.prefix_cache is not None:
                self.stats["prompt_tokens"] = (
                    self.stats.get("prompt_tokens", 0) + len(req.prompt))
                self.stats["prefill_tokens_saved"] = (
                    self.stats.get("prefill_tokens_saved", 0) + cached)
                if cached:
                    self.stats["prefix_hits"] = (
                        self.stats.get("prefix_hits", 0) + 1)
            self._prefilling.append(_InFlight(
                req=req, slot=slot, blocks=blocks, bt_row=bt_row,
                ring_cap=ring_cap, filled=cached, t_admit=now,
                chain=parent, n_hashed=len(hits), draft_pos=cached))

    def _register_blocks(self, st: _InFlight, seq, upto: int) -> None:
        """Register st's fully-written blocks covering positions < upto
        (KV for those positions is in the arena) into the prefix cache."""
        bs = self.pool.block_size
        while (st.n_hashed + 1) * bs <= upto:
            k = st.n_hashed
            st.chain = self.prefix_cache.register(
                st.chain, seq[k * bs:(k + 1) * bs], int(st.bt_row[k]))
            st.n_hashed += 1

    def _finish(self, st: _InFlight, now: float,
                results: dict[int, RequestResult]) -> None:
        if self.prefix_cache is not None:
            # decode wrote KV through position plen + len(out) - 2 (the last
            # sampled token was never fed back), so generated tokens extend
            # the cached chain too — multi-turn prompts hit their history
            seq = np.concatenate([st.req.prompt,
                                  np.asarray(st.out[:-1], np.int32)])
            self._register_blocks(st, seq, len(seq))
        # children (later blocks) enter the idle LRU first, so eviction
        # under pressure reclaims leaves before the prefixes they chain off
        for b in reversed(st.blocks):
            self.allocator.decref(b)
        self.free_slots.append(st.slot)
        del self._active[st.slot]
        results[st.req.rid] = RequestResult(
            rid=st.req.rid, tokens=np.asarray(st.out, np.int32),
            t_admit=st.t_admit, t_first=st.t_first, t_done=now)

    def _prefill_one(self, t0: float,
                     results: dict[int, RequestResult]) -> None:
        st = self._prefilling[0]
        plen = len(st.req.prompt)
        c = min(self.pool.prefill_chunk, plen - st.filled)
        if self.has_attn:
            c = min(c, st.ring_cap)   # scatter uniqueness within a chunk
        toks = jnp.asarray(st.req.prompt[st.filled:st.filled + c],
                           jnp.int32)[None]
        with self._kernel_scope():
            logits, self.caches = self._chunk(
                self.params, self.caches, toks, jnp.int32(st.filled),
                jnp.int32(st.slot), jnp.asarray(st.bt_row),
                jnp.int32(st.ring_cap))
            if self.speculating:
                # the draft arena must hold the prompt too — prefill it in
                # the same chunks (cheap: the draft's packed codes are the
                # low-budget quantization); its logits are unused
                _, self.draft_caches = self._chunk(
                    self.draft_params, self.draft_caches, toks,
                    jnp.int32(st.filled), jnp.int32(st.slot),
                    jnp.asarray(st.bt_row), jnp.int32(st.ring_cap))
        st.filled += c
        if self.speculating:
            st.draft_pos = st.filled
        self.stats["prefill_chunks"] = self.stats.get("prefill_chunks", 0) + 1
        self.stats["prefill_tokens"] = self.stats.get("prefill_tokens", 0) + c
        if self.prefix_cache is not None:
            # blocks completed by this chunk are fully written: publish them
            # so concurrent requests sharing the prompt hit them immediately
            self._register_blocks(st, st.req.prompt, st.filled)
        if st.filled == plen:
            self._prefilling.popleft()
            tok = self._sample(np.asarray(logits[0]), st.req.rid, 0)
            now = time.monotonic() - t0       # after the step has completed
            st.out.append(tok)
            st.t_first = now
            if len(st.out) >= st.req.max_new or tok == st.req.eos:
                self._active[st.slot] = st   # _finish expects it registered
                self._finish(st, now, results)
            else:
                self._active[st.slot] = st

    def _decode_once(self, t0: float,
                     results: dict[int, RequestResult]) -> None:
        s = self.pool.max_slots
        tokens = np.zeros((s, 1), np.int32)
        pos = np.zeros(s, np.int32)
        active = np.zeros(s, bool)
        bts = np.zeros((s, self.table_width), np.int32)
        ring = np.ones(s, np.int32)
        for slot, st in self._active.items():
            tokens[slot, 0] = st.out[-1]
            pos[slot] = len(st.req.prompt) + len(st.out) - 1
            active[slot] = True
            bts[slot] = st.bt_row
            ring[slot] = st.ring_cap
        with self._kernel_scope():
            logits, self.caches = self._step(
                self.params, self.caches, jnp.asarray(tokens),
                jnp.asarray(pos), jnp.asarray(active), jnp.asarray(bts),
                jnp.asarray(ring))
        logits = np.asarray(logits)
        now = time.monotonic() - t0           # after the step has completed
        self.stats["decode_steps"] = self.stats.get("decode_steps", 0) + 1
        self.stats.setdefault("occupancy", []).append(
            len(self._active) / self.pool.max_slots)
        for slot in list(self._active):
            st = self._active[slot]
            tok = self._sample(logits[slot], st.req.rid, len(st.out))
            st.out.append(tok)
            if len(st.out) >= st.req.max_new or tok == st.req.eos:
                self._finish(st, now, results)

    # ---------------------------------------------------------- speculation

    def _spec_decode_once(self, t0: float,
                          results: dict[int, RequestResult]) -> None:
        """One draft-propose / target-verify round over the whole slot set.

        Draft phase: a fixed-shape 2-token catch-up step (feeds the tokens
        at positions pos-1 and pos; the first position's arena write is
        masked unless that slot has a post-all-accept hole) followed by k-1
        single-token draft steps, yielding k proposals per slot and the
        draft logits each was sampled from.  Verify phase: the target
        scores [last, d_1..d_k] at positions pos..pos+k in one batched
        ``decode_verify_paged`` dispatch.  Acceptance runs host-side per
        slot (``speculative_accept``), emitting 1..k+1 tokens per round.
        """
        s, k = self.pool.max_slots, self.speculate
        catch = np.zeros((s, 2), np.int32)    # tokens at pos-1, pos
        pos = np.zeros(s, np.int32)
        active = np.zeros(s, bool)
        hole = np.zeros(s, bool)
        bts = np.zeros((s, self.table_width), np.int32)
        ring = np.ones(s, np.int32)
        for slot, st in self._active.items():
            p = len(st.req.prompt) + len(st.out) - 1
            pos[slot] = p
            catch[slot, 0] = (st.out[-2] if len(st.out) >= 2
                              else st.req.prompt[-1])
            catch[slot, 1] = st.out[-1]
            active[slot] = True
            # after an all-accept round the bonus token's predecessor (d_k)
            # was never fed to the draft: position p-1 is a hole the
            # catch-up step must commit; otherwise the rewrite is masked so
            # shared prefix-cache blocks are never touched
            hole[slot] = st.draft_pos == p - 1
            bts[slot] = st.bt_row
            ring[slot] = st.ring_cap
        wmask = np.ones((s, 2), bool)
        wmask[:, 0] = hole
        with self._kernel_scope():
            dlog, self.draft_caches = self._catchup(
                self.draft_params, self.draft_caches, jnp.asarray(catch),
                jnp.asarray(pos - 1), jnp.asarray(active), jnp.asarray(bts),
                jnp.asarray(ring), jnp.asarray(wmask))
        dl = np.asarray(dlog[:, 1])           # draft logits at position pos
        draft_logits = np.zeros((s, k) + dl.shape[1:], np.float32)
        draft_tokens = np.zeros((s, k), np.int32)
        toks = np.zeros((s, 1), np.int32)
        for i in range(k):
            draft_logits[:, i] = dl
            for slot, st in self._active.items():
                d = self._draft_sample(dl[slot], st.req.rid, len(st.out), i)
                draft_tokens[slot, i] = d
                toks[slot, 0] = d
            if i < k - 1:
                with self._kernel_scope():
                    nxt, self.draft_caches = self._draft_step(
                        self.draft_params, self.draft_caches,
                        jnp.asarray(toks), jnp.asarray(pos + 1 + i),
                        jnp.asarray(active), jnp.asarray(bts),
                        jnp.asarray(ring))
                dl = np.asarray(nxt)
        verify_toks = np.concatenate([catch[:, 1:2], draft_tokens], axis=1)
        with self._kernel_scope():
            tlog, self.caches = self._verify(
                self.params, self.caches, jnp.asarray(verify_toks),
                jnp.asarray(pos), jnp.asarray(active), jnp.asarray(bts),
                jnp.asarray(ring), jnp.ones((s, k + 1), bool))
        tlog = np.asarray(tlog)
        now = time.monotonic() - t0           # after the step has completed
        self.stats["spec_rounds"] = self.stats.get("spec_rounds", 0) + 1
        self.stats.setdefault("occupancy", []).append(
            len(self._active) / self.pool.max_slots)
        for slot in list(self._active):
            st = self._active[slot]
            # greedy needs no RNG (and warmup requests may carry negative
            # rids, which SeedSequence rejects)
            rng = (np.random.default_rng(
                       (self.seed, st.req.rid, len(st.out), 7))
                   if self.temperature > 0.0 else None)
            emitted, n_acc = speculative_accept(
                tlog[slot], draft_logits[slot], draft_tokens[slot],
                self.temperature, rng)
            self.stats["spec_proposed"] = (
                self.stats.get("spec_proposed", 0) + k)
            self.stats["spec_accepted"] = (
                self.stats.get("spec_accepted", 0) + n_acc)
            p = int(pos[slot])
            # draft KV is valid through the last accepted draft position
            # (the replacement/bonus token is never fed to the draft)
            st.draft_pos = min(p + n_acc + 1, p + k)
            for tok in emitted:
                st.out.append(int(tok))
                if (len(st.out) >= st.req.max_new or tok == st.req.eos):
                    break
            if len(st.out) >= st.req.max_new or st.out[-1] == st.req.eos:
                self._finish(st, now, results)

    # ------------------------------------------------------------------ run

    def run(self, requests: list[Request] | None = None
            ) -> dict[int, RequestResult]:
        """Serve until every submitted request completes.  Returns
        rid -> RequestResult; aggregate stats land in ``self.stats``
        (occupancy, prefill/prefix counters, and — when speculating —
        spec_rounds / spec_proposed / spec_accepted / acceptance_rate)."""
        for r in requests or []:
            self.submit(r)
        self._pending = collections.deque(
            sorted(self._pending, key=lambda r: r.arrival))
        results: dict[int, RequestResult] = {}
        t0 = time.monotonic()
        while self._pending or self._prefilling or self._active:
            self._try_admit(time.monotonic() - t0)
            if self._prefilling:
                self._prefill_one(t0, results)
            if self._active:
                if self.speculate:
                    self._spec_decode_once(t0, results)
                else:
                    self._decode_once(t0, results)
            elif not self._prefilling:
                if self._pending:
                    wait = self._pending[0].arrival - (time.monotonic() - t0)
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
        occ = self.stats.get("occupancy", [])
        self.stats["mean_occupancy"] = float(np.mean(occ)) if occ else 0.0
        if self.speculate:
            prop = self.stats.get("spec_proposed", 0)
            self.stats["acceptance_rate"] = (
                self.stats.get("spec_accepted", 0) / prop if prop else 0.0)
        if self.prefix_cache is not None:
            pt = self.stats.get("prompt_tokens", 0)
            self.stats["prefix_hit_rate"] = (
                self.stats.get("prefill_tokens_saved", 0) / pt if pt else 0.0)
            self.stats["prefix_evictions"] = self.prefix_cache.evictions
            self.stats["prefix_cached_blocks"] = len(self.prefix_cache)
        return results
