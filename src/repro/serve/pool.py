"""Paged KV-cache pool: a fixed arena of (num_blocks, block_size, KV, hd)
blocks shared by all in-flight requests, plus per-slot state arrays for the
cache types that are O(1) or latent-compressed per token.

Layout (mirrors ``params["layers"]`` / ``decode.init_caches`` so the paged
decode step scans layers and pool state together):

  attn   -> {"k": (n_j, N, bs, KV, hd), "v": ...}   one arena per layer; a
            physical block id addresses the same (bs, KV, hd) slab in every
            layer's arena, so one block table serves the whole stack
  mla    -> {"mla": MLACache((n_j, S, cap, kv_lora), ...)}  per-slot rows
  rwkv   -> {"rwkv": RWKVState((n_j, S, H, dk, dk), ...)}   per-slot rows
  rglru  -> {"rglru": RGLRUState((n_j, S, dr), ...)}        per-slot rows

Physical block 0 is the null block: unallocated block-table entries point at
it and inactive-slot writes are redirected to it; validity masks derived from
per-slot positions guarantee it is never read as a real key.  Sliding-window
archs allocate only ceil(window / block_size) blocks per request and reuse
them as a ring (ring-window reuse), so a long generation holds a bounded
number of blocks no matter how many tokens it emits.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import decode as decmod
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Sizing of the paged pool; all shapes derived here are static, so the
    jitted decode step compiles once per (model, PoolConfig)."""
    max_slots: int = 8          # concurrent in-flight requests
    block_size: int = 16        # tokens per KV block
    max_context: int = 512      # per-request cap (prompt + generation)
    num_blocks: int | None = None   # arena size; default fits every slot at
    #   max_context simultaneously (i.e. admission never blocks on blocks)
    prefill_chunk: int = 32     # prompt tokens per engine iteration

    def resolved_num_blocks(self, cfg: ModelConfig) -> int:
        if self.num_blocks is not None:
            return self.num_blocks
        per = request_blocks(cfg, self, self.max_context)
        return 1 + self.max_slots * max(per, 1)   # +1: null block


def request_blocks(cfg: ModelConfig, pool: PoolConfig, total_len: int) -> int:
    """Blocks a request of ``total_len`` tokens needs (0 for attention-free
    archs).  Sliding-window archs are capped at the window: their blocks are
    ring-reused in place."""
    if "attn" not in cfg.pattern:
        return 0
    cap = decmod.attn_capacity(cfg, total_len)
    return -(-cap // pool.block_size)


class BlockAllocator:
    """Host-side free list over physical blocks; block 0 is reserved."""

    def __init__(self, num_blocks: int):
        assert num_blocks >= 2, "need at least the null block + one real block"
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))   # pop() -> 1, 2, ...

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """n physical block ids, or None if the pool can't satisfy it now."""
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, blocks: list[int]) -> None:
        self._free.extend(blocks)


def init_pool_caches(cfg: ModelConfig, params: dict, pool: PoolConfig,
                     dtype=jnp.float32) -> list:
    """Device-side pool state, stacked parallel to ``params['layers']``."""
    if cfg.enc_dec:
        raise ValueError("paged pool does not support encoder-decoder archs")
    num_blocks = pool.resolved_num_blocks(cfg)
    pat, p = cfg.pattern, cfg.scan_period
    caches = []
    for j in range(p):
        stack = params["layers"][j]
        n_j = (len(stack) if isinstance(stack, list)
               else jax.tree.leaves(stack)[0].shape[0])

        def one(mixer):
            if mixer == "attn":
                shape = (num_blocks, pool.block_size, cfg.n_kv, cfg.hd)
                return {"k": jnp.zeros(shape, dtype),
                        "v": jnp.zeros(shape, dtype)}
            return decmod.init_layer_cache(cfg, mixer, pool.max_slots,
                                           pool.max_context, dtype)

        caches.append(jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                                   *[one(pat[j]) for _ in range(n_j)]))
    return caches
