"""Paged KV-cache pool: a fixed arena of (num_blocks, block_size, KV, hd)
blocks shared by all in-flight requests, plus per-slot state arrays for the
cache types that are O(1) or latent-compressed per token.

Layout (mirrors ``params["layers"]`` / ``decode.init_caches`` so the paged
decode step scans layers and pool state together):

  attn   -> {"k": (n_j, N, bs, KV, hd), "v": ...}   one arena per layer; a
            physical block id addresses the same (bs, KV, hd) slab in every
            layer's arena, so one block table serves the whole stack
  mla    -> {"mla": MLACache((n_j, S, cap, kv_lora), ...)}  per-slot rows
  rwkv   -> {"rwkv": RWKVState((n_j, S, H, dk, dk), ...)}   per-slot rows
  rglru  -> {"rglru": RGLRUState((n_j, S, dr), ...)}        per-slot rows

Physical block 0 is the null block: unallocated block-table entries point at
it and inactive-slot writes are redirected to it; validity masks derived from
per-slot positions guarantee it is never read as a real key.  Sliding-window
archs allocate only ceil(window / block_size) blocks per request and reuse
them as a ring (ring-window reuse), so a long generation holds a bounded
number of blocks no matter how many tokens it emits.

Block ownership (DESIGN.md §8): ``BlockAllocator`` refcounts every live
block.  A block whose refcount drops to zero returns to the free list unless
its contents are registered in the ``PrefixCache`` — then it parks on an LRU
list, still holding its KV, and is evicted (hash entry dropped, block
reusable) only when an allocation cannot be met from the free list.  The
cache itself is content-addressed: full blocks are keyed by a hash chain
over (parent_hash, block_tokens), so a lookup walks the prompt block by
block and two requests sharing a prompt prefix share physical blocks.

The same park-on-LRU mechanics carry drop-and-replay preemption
(DESIGN.md §12): before the engine evicts an in-flight victim it registers
the victim's fully-written blocks — keyed by the victim's own
prompt+generated hash chain, exactly as if a second request had presented
that sequence as its prompt — so the blocks survive refcount release with
their KV intact, the replay's prefill walks them as ordinary cache hits,
and under allocation pressure they age out through the ordinary LRU path
(a preempted request's parked history is reclaimable capacity, never a
reservation).

Tensor parallelism (DESIGN.md §11): the arena's device placement is the
engine's business, not the pool's — under ``--tp N`` the KV-head axis of
every attention arena is sharded over the mesh's ``"model"`` axis while
*all host-side pool state here* (block tables, ``BlockAllocator`` refcounts
and free list, ``PrefixCache`` hash chain, per-slot positions) stays
replicated python state: block ids are device-agnostic, so one allocator
decision drives every shard identically and the prefix cache never needs
to know the arena is distributed.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import decode as decmod
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static sizing of the paged KV pool.

    Every shape the jitted serving steps see is derived from these fields,
    so one engine compiles its decode/prefill/verify steps exactly once per
    (model, PoolConfig) — batch churn changes array *contents* only.
    ``num_blocks=None`` sizes the arena so every slot can hold a
    ``max_context`` request simultaneously (admission never blocks on
    blocks); pass an explicit count to exercise allocation pressure.
    ``lookahead`` is extra per-request ring capacity in tokens, reserved by
    the speculative engine (set automatically to its ``speculate`` depth) so
    verify-step writes for later-rejected draft tokens can never clobber
    still-needed history — see DESIGN.md §9.
    """
    max_slots: int = 8          # concurrent in-flight requests
    block_size: int = 16        # tokens per KV block
    max_context: int = 512      # per-request cap (prompt + generation)
    num_blocks: int | None = None   # arena size; default fits every slot at
    #   max_context simultaneously (i.e. admission never blocks on blocks)
    prefill_chunk: int = 32     # prompt tokens per engine iteration
    prefix_cache: bool = True   # content-addressed KV block reuse (engines
    #   enable it only for archs whose blocks are immutable once written)
    kv_dtype: Any = jnp.float32  # arena + per-slot state dtype (f32 | bf16)
    lookahead: int = 0          # extra ring tokens for speculative writes

    def resolved_num_blocks(self, cfg: ModelConfig) -> int:
        """Arena size in physical blocks (the +1 is the null block)."""
        if self.num_blocks is not None:
            return self.num_blocks
        per = request_blocks(cfg, self, self.max_context)
        return 1 + self.max_slots * max(per, 1)   # +1: null block


def request_blocks(cfg: ModelConfig, pool: PoolConfig, total_len: int) -> int:
    """Blocks a request of ``total_len`` tokens needs (0 for attention-free
    archs).  Sliding-window archs are capped at the window: their blocks are
    ring-reused in place.  ``pool.lookahead`` tokens are added on top of the
    capped capacity so a speculating engine can write draft/verify KV up to
    ``lookahead`` positions past the accepted frontier without wrapping onto
    live history (rejected-token writes land in slots the stored-position
    validity masks already exclude)."""
    if "attn" not in cfg.pattern:
        return 0
    cap = decmod.attn_capacity(cfg, total_len) + pool.lookahead
    return -(-cap // pool.block_size)


class PrefixCache:
    """Content-addressed index over full KV blocks.

    A block holding tokens ``t`` whose predecessor blocks hash to ``parent``
    is keyed by ``chain_hash(parent, t)``; the chain root is ``None``.  The
    index only *names* blocks — ownership (refcounts, eviction order) lives
    in ``BlockAllocator``, which calls :meth:`_evict` when it reclaims a
    cached block under allocation pressure.
    """

    ROOT = None

    def __init__(self, block_size: int):
        self.block_size = block_size
        self._by_hash: dict = {}     # hash -> (block, parent, tokens)
        self._by_block: dict = {}    # block -> hash
        self._children: dict = {}    # parent hash -> set of child hashes
        self.evictions = 0

    @staticmethod
    def chain_hash(parent, tokens) -> int:
        return hash((parent,) + tuple(int(t) for t in tokens))

    def __len__(self) -> int:
        return len(self._by_hash)

    def contains_block(self, block: int) -> bool:
        return block in self._by_block

    def register(self, parent, tokens, block: int):
        """Register a fully-written block; first content wins (an existing
        entry for the same chain keeps its block).  Returns the chain hash,
        which is the ``parent`` for the request's next block."""
        h = self.chain_hash(parent, tokens)
        if h not in self._by_hash:
            self._by_hash[h] = (block, parent,
                                tuple(int(t) for t in tokens))
            self._by_block[block] = h
            self._children.setdefault(parent, set()).add(h)
        return h

    def match(self, prompt, max_tokens: int):
        """Longest cached prefix of ``prompt``, capped at ``max_tokens``
        (callers pass plen - 1 so at least one prompt token is always
        recomputed to produce first-token logits).

        Returns ``(hit_blocks, parent_hash, cached_tokens, cow_block)``:
        ``hit_blocks`` are whole-block hits in prompt order;
        ``cached_tokens = len(hit_blocks) * bs + lcp`` where ``lcp > 0``
        means ``cow_block`` is a cached block whose first ``lcp`` tokens
        match the prompt past the last full hit — the caller must take a
        private copy-on-write copy before writing positions ``>= cached``.
        """
        bs = self.block_size
        hits: list[int] = []
        parent = self.ROOT
        k = 0
        while (k + 1) * bs <= max_tokens:
            block_toks = tuple(int(t) for t in prompt[k * bs:(k + 1) * bs])
            h = self.chain_hash(parent, block_toks)
            ent = self._by_hash.get(h)
            # a hash hit alone is not trusted: the stored token tuple must
            # match too, or a chain_hash collision would serve another
            # request's KV (the partial path below compares tokens directly)
            if ent is None or ent[2] != block_toks:
                break
            hits.append(ent[0])
            parent = h
            k += 1
        cached = k * bs
        # mid-block divergence: the longest token-level common prefix among
        # the cached children of the last fully-matched block
        cow: Optional[int] = None
        best = 0
        rest = [int(t) for t in prompt[cached:max_tokens]]
        if rest:
            for h in self._children.get(parent, ()):
                ent = self._by_hash.get(h)
                if ent is None:
                    continue
                blk, _, toks = ent
                lcp = 0
                for a, b in zip(rest, toks):
                    if a != b:
                        break
                    lcp += 1
                if lcp > best:
                    best, cow = lcp, blk
        return hits, parent, cached + best, cow

    def _evict(self, block: int) -> None:
        """Drop the entry naming ``block`` (allocator reclaimed it).  A child
        chained off an evicted parent becomes unreachable to ``match`` and
        ages out of the LRU on its own."""
        h = self._by_block.pop(block)
        _, parent, _ = self._by_hash.pop(h)
        kids = self._children.get(parent)
        if kids is not None:
            kids.discard(h)
            if not kids:
                del self._children[parent]
        self.evictions += 1


class BlockAllocator:
    """Host-side refcounted ownership of physical blocks; block 0 reserved.

    Free blocks live on ``_free``; referenced blocks in ``_ref`` (block ->
    count); cached-but-unreferenced blocks park in ``_lru`` (insertion order
    = eviction order) and are reclaimed — oldest first, with the attached
    ``PrefixCache`` notified — only when ``alloc`` outgrows the free list.
    """

    def __init__(self, num_blocks: int, cache: PrefixCache | None = None):
        assert num_blocks >= 2, "need at least the null block + one real block"
        self.num_blocks = num_blocks
        self.cache = cache
        self._free = list(range(num_blocks - 1, 0, -1))   # pop() -> 1, 2, ...
        self._ref: dict[int, int] = {}
        self._lru: collections.OrderedDict[int, bool] = (
            collections.OrderedDict())

    @property
    def free_blocks(self) -> int:
        """Blocks an ``alloc`` could hand out right now (cached idle blocks
        are reclaimable, so they count)."""
        return len(self._free) + len(self._lru)

    @property
    def cached_idle_blocks(self) -> int:
        return len(self._lru)

    def alloc(self, n: int) -> list[int] | None:
        """n private block ids (refcount 1 each), or None if the pool can't
        satisfy it now.  Evicts LRU cached blocks only under pressure."""
        if n > self.free_blocks:
            return None
        out = []
        for _ in range(n):
            if self._free:
                b = self._free.pop()
            else:
                b, _ = self._lru.popitem(last=False)     # LRU eviction
                if self.cache is not None:
                    self.cache._evict(b)
            self._ref[b] = 1
            out.append(b)
        return out

    def incref(self, block: int) -> None:
        """Take a reference on a live or cached-idle block (prefix hit)."""
        if block in self._ref:
            self._ref[block] += 1
        else:
            del self._lru[block]                          # revive from LRU
            self._ref[block] = 1

    def decref(self, block: int) -> None:
        """Release one reference; a block at zero parks in the LRU if its
        contents are cached, else returns to the free list."""
        r = self._ref[block] - 1
        if r > 0:
            self._ref[block] = r
            return
        del self._ref[block]
        if self.cache is not None and self.cache.contains_block(block):
            self._lru[block] = True
        else:
            self._free.append(block)

    def free(self, blocks: list[int]) -> None:
        """Release one reference on each block (request teardown)."""
        for b in blocks:
            self.decref(b)


def init_pool_caches(cfg: ModelConfig, params: dict, pool: PoolConfig,
                     dtype=None) -> list:
    """Device-side pool state, stacked parallel to ``params['layers']``.
    ``dtype`` defaults to ``pool.kv_dtype``."""
    if cfg.enc_dec:
        raise ValueError("paged pool does not support encoder-decoder archs")
    if dtype is None:
        dtype = pool.kv_dtype
    num_blocks = pool.resolved_num_blocks(cfg)
    pat, p = cfg.pattern, cfg.scan_period
    caches = []
    for j in range(p):
        stack = params["layers"][j]
        n_j = (len(stack) if isinstance(stack, list)
               else jax.tree.leaves(stack)[0].shape[0])

        def one(mixer):
            if mixer == "attn":
                shape = (num_blocks, pool.block_size, cfg.n_kv, cfg.hd)
                return {"k": jnp.zeros(shape, dtype),
                        "v": jnp.zeros(shape, dtype)}
            return decmod.init_layer_cache(cfg, mixer, pool.max_slots,
                                           pool.max_context, dtype)

        caches.append(jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                                   *[one(pat[j]) for _ in range(n_j)]))
    return caches
