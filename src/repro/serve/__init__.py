"""Continuous-batching serving engine with a paged KV-cache pool.

``pool``      — fixed block arena + per-request block tables + slot arrays;
                refcounted block ownership + content-addressed prefix cache.
``engine``    — request queue, admission control (with prefix reuse / COW),
                chunked prefill interleaved with decode, per-request
                completion with streaming ``on_token`` emission, re-entrant
                ``step()``/``poll()`` driving, drop-and-replay
                ``preempt()``, and optional self-speculative decoding (a
                low-bit draft quantization proposes tokens the target
                verifies in one batched step; DESIGN.md §9).
``frontdoor`` — the async serving layer over the engine: priority/fair-share
                ``Scheduler`` with SLO-aware prefill throttling, the
                asyncio HTTP/SSE server, and a stdlib streaming client
                (DESIGN.md §12).
"""
from .engine import PagedServer, Request, RequestResult, speculative_accept
from .pool import (BlockAllocator, PoolConfig, PrefixCache, init_pool_caches,
                   request_blocks)

__all__ = ["PagedServer", "Request", "RequestResult", "BlockAllocator",
           "PoolConfig", "PrefixCache", "init_pool_caches", "request_blocks",
           "speculative_accept"]
