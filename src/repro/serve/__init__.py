"""Continuous-batching serving engine with a paged KV-cache pool.

``pool``   — fixed block arena + per-request block tables + slot arrays.
``engine`` — request queue, admission control, chunked prefill interleaved
             with decode, per-request completion.
"""
from .engine import PagedServer, Request
from .pool import BlockAllocator, PoolConfig, init_pool_caches, request_blocks

__all__ = ["PagedServer", "Request", "BlockAllocator", "PoolConfig",
           "init_pool_caches", "request_blocks"]
