"""Continuous-batching serving engine with a paged KV-cache pool.

``pool``   — fixed block arena + per-request block tables + slot arrays;
             refcounted block ownership + content-addressed prefix cache.
``engine`` — request queue, admission control (with prefix reuse / COW),
             chunked prefill interleaved with decode, per-request completion.
"""
from .engine import PagedServer, Request
from .pool import (BlockAllocator, PoolConfig, PrefixCache, init_pool_caches,
                   request_blocks)

__all__ = ["PagedServer", "Request", "BlockAllocator", "PoolConfig",
           "PrefixCache", "init_pool_caches", "request_blocks"]
