"""Async streaming front door over the paged serving engine (DESIGN.md §12).

``scheduler`` — ``Scheduler``: per-tenant priority admission queues with
                weighted fair sharing, drop-and-replay preemption of the
                engine's in-flight requests, and an SLO controller that
                throttles chunked-prefill admission (with hysteresis) when
                decode p95 degrades past a target.
``server``    — ``FrontDoor``: hand-rolled asyncio HTTP server exposing
                ``POST /v1/generate`` with per-token SSE streaming (plus
                ``/healthz`` and ``/v1/stats``), driving the engine +
                scheduler on a background thread.
``sse``       — Server-Sent-Events wire format (encode + incremental parse),
                shared by server and client.
``client``    — stdlib-only streaming client (``stream_generate``) and a
                tiny CLI (``python -m repro.serve.frontdoor.client``).
"""
from .scheduler import SchedConfig, Scheduler
from .server import FrontDoor
from .sse import encode_event, iter_events

__all__ = ["SchedConfig", "Scheduler", "FrontDoor", "encode_event",
           "iter_events"]
