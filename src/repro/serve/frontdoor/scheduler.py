"""SLO-aware admission scheduler over the paged engine (DESIGN.md §12).

The engine (``PagedServer``) admits FIFO; this layer decides *what reaches
that FIFO and when*.  Three policies compose:

- **Priority admission with weighted fair sharing.**  Requests queue per
  tenant, ordered by ``Request.priority`` (higher first) then submit order.
  Across tenants the next admission goes to the highest-priority queue
  head; ties break toward the tenant with the smallest weighted load
  (slots held / weight), so two same-priority tenants with weights 2:1
  converge to a 2:1 slot split.  ``max_tenant_share`` caps the fraction of
  slots any tenant may hold while others are waiting.

- **Preemption via drop-and-replay.**  When the best waiting request
  cannot be admitted (no slot / no blocks) and a strictly-lower-priority
  request is in flight — or a tenant is over its share cap while another
  waits below it — the scheduler calls ``engine.preempt`` on the victim
  (lowest priority first; among equals the most recently admitted, which
  has the least work to replay) and requeues it.  The engine registers the
  victim's generated KV blocks in the prefix cache before dropping them,
  so the replay is a warm prefill, and the replayed greedy output is
  token-identical to an uninterrupted run.

- **SLO control with hysteresis.**  The engine records the gap between
  consecutive decode steps (``decode_gaps``) — the per-token latency a
  decoding request observes, inflated by interleaved prefill chunks.  When
  the windowed p95 of that gap exceeds ``slo_p95_ms``, the controller
  throttles chunked-prefill admission (``engine.step(prefill=False)``);
  prefill resumes only once p95 falls below ``slo_resume_frac`` of the
  target, so the loop duty-cycles instead of flapping on every sample.
  Prefill is never throttled while nothing is decoding (no SLO to protect,
  and holding it would deadlock).

The scheduler owns no thread: ``tick()`` is one admission + preemption +
engine-step round, driven by whoever owns the serving thread (the HTTP
front door's driver loop, or a benchmark loop).  Requests must be
submitted when due — ``Request.arrival`` is metadata for latency
accounting, not a future-scheduling mechanism (queue heads with a future
arrival simply wait).
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    """Front-door scheduling policy knobs.

    ``slo_p95_ms = None`` disables the SLO controller entirely (prefill is
    always admitted).  ``max_tenant_share = 1.0`` disables the share cap.
    """
    slo_p95_ms: float | None = None   # decode-gap p95 target (milliseconds)
    slo_window: int = 32              # gap samples in the p95 window
    slo_min_samples: int = 8          # don't judge p95 on fewer gaps
    slo_resume_frac: float = 0.7      # hysteresis: resume below frac*target
    max_tenant_share: float = 1.0     # max fraction of slots per tenant
    preemption: bool = True           # allow drop-and-replay eviction


class Scheduler:
    """Priority / fair-share / SLO admission layer over a ``PagedServer``.

    The engine surface consumed here (and stubbed by the unit tests'
    FakeEngine): ``pool.max_slots``, ``active_count``, ``decode_gaps``,
    ``validate``, ``submit``, ``can_admit``, ``preempt``, ``inflight``,
    ``step(prefill=)``, ``poll``, ``now``.
    """

    def __init__(self, engine, cfg: SchedConfig | None = None):
        self.engine = engine
        self.cfg = cfg or SchedConfig()
        if not (0.0 < self.cfg.max_tenant_share <= 1.0):
            raise ValueError("max_tenant_share must be in (0, 1]")
        # tenant -> heap of (-priority, seq, Request); seq keeps FIFO order
        # among equal priorities and makes heap entries totally ordered
        self._queues: dict[str, list] = {}
        self._weights: dict[str, float] = {}
        self._seq = 0
        self.throttled = False
        self.last_p95_ms: float | None = None
        self.stats: collections.Counter = collections.Counter()

    # ----------------------------------------------------------- submission

    def submit(self, req, weight: float = 1.0) -> None:
        """Queue ``req`` on its tenant's priority queue.  ``weight`` is the
        tenant's fair-share weight (last submit wins; default 1.0 —
        unweighted fair sharing)."""
        if weight <= 0.0:
            raise ValueError("tenant weight must be > 0")
        self.engine.validate(req)
        self._weights[req.tenant] = float(weight)
        heapq.heappush(self._queues.setdefault(req.tenant, []),
                       (-req.priority, self._seq, req))
        self._seq += 1

    def _requeue(self, req) -> None:
        """Put a preempted request back; it competes at its own priority
        behind already-queued equals (no starvation of the queue)."""
        heapq.heappush(self._queues.setdefault(req.tenant, []),
                       (-req.priority, self._seq, req))
        self._seq += 1

    def cancel(self, rid: int) -> bool:
        """Drop ``rid`` from the tenant queues or the engine (wherever it
        is); the front door calls this when a streaming client goes away."""
        for q in self._queues.values():
            for i, (_p, _s, r) in enumerate(q):
                if r.rid == rid:
                    q.pop(i)
                    heapq.heapify(q)
                    self.stats["cancelled"] += 1
                    return True
        return self.engine.cancel(rid)

    def has_work(self) -> bool:
        return any(self._queues.values()) or self.engine.poll()

    def queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # ---------------------------------------------------------- fair share

    def _held(self) -> collections.Counter:
        """Slots (or imminent slots) held per tenant: everything the engine
        has accepted — pending admissions included, since they were already
        granted by a previous ``_admit`` round."""
        held = collections.Counter()
        for req, _phase, _done, _t in self.engine.inflight():
            held[req.tenant] += 1
        return held

    def share_cap(self) -> int:
        """Max slots one tenant may hold while another tenant waits."""
        return max(1, math.ceil(self.cfg.max_tenant_share
                                * self.engine.pool.max_slots))

    def _pick(self, now: float):
        """The next request admission should take: the highest-priority due
        queue head, ties broken by smallest weighted load then FIFO.
        Tenants at the share cap stand aside while any other tenant has
        due work.  Returns ``(tenant, request)`` or ``None``."""
        held = self._held()
        cap = self.share_cap()
        due = []
        for tenant, q in self._queues.items():
            if not q:
                continue
            neg_prio, seq, req = q[0]
            if req.arrival > now:
                continue
            due.append((tenant, neg_prio, seq, req))
        if not due:
            return None
        capped_out = [d for d in due if held[d[0]] < cap]
        if capped_out:
            due = capped_out        # cap binds only while others wait
        best = min(due, key=lambda d: (d[1],
                                       held[d[0]] / self._weights[d[0]],
                                       d[2]))
        return best[0], best[3]

    def _admit(self, now: float) -> None:
        while True:
            pick = self._pick(now)
            if pick is None:
                return
            tenant, req = pick
            if not self.engine.can_admit(req):
                return
            heapq.heappop(self._queues[tenant])
            self.engine.submit(req)
            self.stats["admitted"] += 1

    # ---------------------------------------------------------- preemption

    def _maybe_preempt(self, now: float) -> None:
        """Evict at most one victim per tick to make room for the best
        waiting request: a strictly-lower-priority in-flight request, or —
        when the waiter's tenant is under the share cap — an equal-or-lower
        priority request of a tenant over it."""
        if not self.cfg.preemption:
            return
        pick = self._pick(now)
        if pick is None:
            return
        tenant, req = pick
        if self.engine.can_admit(req):
            return                    # plain admission will take it
        held = self._held()
        cap = self.share_cap()
        running = [(r, done, t_admit)
                   for r, phase, done, t_admit in self.engine.inflight()
                   if phase in ("prefill", "decode")]
        victims = [v for v in running if v[0].priority < req.priority]
        if not victims and held[tenant] < cap:
            victims = [v for v in running
                       if held[v[0].tenant] > cap and v[0].tenant != tenant
                       and v[0].priority <= req.priority]
        if not victims:
            return
        # lowest priority first; among equals the most recently admitted
        # (least completed work to replay)
        victim = min(victims, key=lambda v: (v[0].priority, -v[2]))
        r = self.engine.preempt(victim[0].rid)
        if r is not None:
            self._requeue(r)
            self.stats["preempted"] += 1
            self.stats[f"preempted.{r.tenant}"] += 1

    # ------------------------------------------------------- SLO controller

    def _update_slo(self) -> None:
        cfg = self.cfg
        if cfg.slo_p95_ms is None:
            return
        gaps = self.engine.decode_gaps
        if len(gaps) < cfg.slo_min_samples:
            return
        window = list(gaps)[-cfg.slo_window:]
        p95_ms = float(np.percentile(window, 95)) * 1e3
        self.last_p95_ms = p95_ms
        if not self.throttled and p95_ms > cfg.slo_p95_ms:
            self.throttled = True
            self.stats["slo_throttle_on"] += 1
        elif self.throttled and p95_ms < cfg.slo_resume_frac * cfg.slo_p95_ms:
            self.throttled = False
            self.stats["slo_throttle_off"] += 1

    def allow_prefill(self) -> bool:
        """Chunked prefill runs unless the SLO controller is throttled —
        and always runs when nothing is decoding (nothing to protect;
        gating it then could only stall the pool)."""
        return not self.throttled or self.engine.active_count == 0

    # ------------------------------------------------------------------ tick

    def tick(self, now: float | None = None) -> dict:
        """One scheduling round: update the SLO controller, admit due
        requests (priority / fair-share order), preempt if the best waiter
        is blocked behind lower-priority work, then run one engine step
        (prefill gated by the controller).  Returns the requests that
        finished during the step (rid -> RequestResult)."""
        now = self.engine.now() if now is None else now
        self._update_slo()
        self._admit(now)
        self._maybe_preempt(now)
        finished = self.engine.step(prefill=self.allow_prefill())
        if self.throttled:
            self.stats["slo_throttled_ticks"] += 1
        self.stats["completed"] += len(finished)
        return finished
