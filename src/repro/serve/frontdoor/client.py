"""Stdlib streaming client for the front door (and the CI smoke probe).

``stream_generate`` opens one ``POST /v1/generate`` and yields the SSE
``(event, data)`` pairs as they arrive — ``token`` events while the engine
decodes, one terminal ``done`` (or ``error``).  Built on ``http.client``
so it needs nothing outside the standard library; the CI serve-smoke leg
runs the module CLI against a freshly-booted server and exits non-zero
unless it saw at least one token event and a clean ``done``.
"""
from __future__ import annotations

import argparse
import http.client
import json
import sys
from typing import Iterator, Optional, Tuple

from .sse import iter_events


def stream_generate(host: str, port: int, *,
                    prompt: Optional[str] = None,
                    tokens: Optional[list] = None,
                    max_new: int = 16,
                    tenant: str = "default",
                    priority: int = 0,
                    weight: float = 1.0,
                    timeout: float = 120.0,
                    **extra) -> Iterator[Tuple[str, dict]]:
    """POST one generation request and yield its SSE events as parsed
    ``(event, data)`` pairs.  Exactly one of ``prompt`` / ``tokens``."""
    body = {"max_new": max_new, "tenant": tenant, "priority": priority,
            "weight": weight, **extra}
    if tokens is not None:
        body["tokens"] = [int(t) for t in tokens]
    elif prompt is not None:
        body["prompt"] = prompt
    else:
        raise ValueError("need prompt= or tokens=")
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("POST", "/v1/generate", body=json.dumps(body),
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        if resp.status != 200:
            raise RuntimeError(
                f"HTTP {resp.status}: {resp.read().decode(errors='replace')}")
        lines = (raw.decode("utf-8", errors="replace")
                 for raw in iter(resp.readline, b""))
        yield from iter_events(lines)
    finally:
        conn.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Stream one generation from a running front door.")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--prompt", default="the quick brown fox")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--tenant", default="default")
    ap.add_argument("--priority", type=int, default=0)
    args = ap.parse_args(argv)

    n_tokens, done = 0, False
    for event, data in stream_generate(
            args.host, args.port, prompt=args.prompt, max_new=args.max_new,
            tenant=args.tenant, priority=args.priority):
        if event == "token":
            n_tokens += 1
            print(f"token[{n_tokens}] {data.get('token')} "
                  f"{data.get('text')!r}", flush=True)
        elif event == "done":
            done = True
            print(f"done: {data.get('n_tokens')} tokens, "
                  f"ttft={data.get('ttft_s', 0):.3f}s, "
                  f"preemptions={data.get('preemptions')}, "
                  f"text={data.get('text')!r}", flush=True)
        else:
            print(f"{event}: {data}", flush=True)
    ok = done and n_tokens >= 1
    print(f"client: {'OK' if ok else 'FAIL'} "
          f"({n_tokens} token events, done={done})", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
