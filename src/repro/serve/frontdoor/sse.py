"""Server-Sent-Events wire format (the streaming half of DESIGN.md §12).

One event is an ``event:`` line, one or more ``data:`` lines, and a blank
terminator; payloads here are always a single JSON object.  ``encode_event``
is what the server writes; ``iter_events`` is the incremental parser the
bundled client (and the tests) read streams back through.  Lines starting
with ``:`` are SSE comments (keep-alives) and are skipped.
"""
from __future__ import annotations

import json
from typing import Iterable, Iterator, Tuple


def encode_event(event: str, data: dict) -> bytes:
    """One SSE frame: ``event: <name>`` + JSON ``data`` + blank line."""
    return (f"event: {event}\ndata: {json.dumps(data)}\n\n").encode("utf-8")


def iter_events(lines: Iterable[str]) -> Iterator[Tuple[str, dict]]:
    """Parse a stream of text lines into ``(event, data)`` pairs.

    ``lines`` may keep or strip their newlines.  Multiple ``data:`` lines
    concatenate (with ``\\n``, per the SSE spec) before the JSON decode;
    events with no data yield ``{}``.  The unterminated tail of a closed
    stream is ignored, matching browser EventSource behavior.
    """
    event, datas = None, []
    for raw in lines:
        line = raw.rstrip("\r\n")
        if line == "":
            if event is not None or datas:
                payload = json.loads("\n".join(datas)) if datas else {}
                yield (event or "message", payload)
            event, datas = None, []
        elif line.startswith(":"):
            continue
        elif line.startswith("event:"):
            event = line[len("event:"):].strip()
        elif line.startswith("data:"):
            datas.append(line[len("data:"):].strip())
