"""Asyncio HTTP/SSE front door over the paged engine (DESIGN.md §12).

Hand-rolled on ``asyncio.start_server`` — no web framework, no new deps.
One connection carries one HTTP/1.1 request (``Connection: close``):

  ``POST /v1/generate``  JSON body -> per-token SSE stream (or one JSON
                         response with ``"stream": false``)
  ``GET  /healthz``      liveness probe
  ``GET  /v1/stats``     engine + scheduler counters

Threading model: the asyncio event loop owns sockets only.  The engine and
scheduler live on ONE background driver thread (JAX dispatch, block
accounting, and queue state are single-threaded by construction), which
loops ``scheduler.tick()`` whenever there is work.  The bridges between
the two worlds are explicit and small:

- submit: the HTTP handler builds a ``Request`` whose ``on_token`` closure
  posts ``(event, data)`` onto that stream's ``asyncio.Queue`` via
  ``loop.call_soon_threadsafe``, then hands it to the scheduler under
  ``self._lock`` and wakes the driver.
- completion: the driver thread posts the terminal ``done`` (or ``error``)
  event the same way.
- disconnect: a failed SSE write cancels the request through the
  scheduler, so an abandoned stream stops burning pool capacity.

Every generation response streams ``event: token`` frames
(``{"rid", "i", "token", "text", "t"}``) and ends with ``event: done``
(``{"rid", "tokens", "text", "ttft_s", "n_tokens", "preemptions",
"tenant"}``).  Preemption is invisible in the stream except as a pause:
tokens already streamed are never re-sent (the engine re-feeds them as
prompt on replay, emitting only genuinely new tokens).
"""
from __future__ import annotations

import asyncio
import contextlib
import itertools
import json
import signal
import threading
import traceback

import numpy as np

from ..engine import Request
from .scheduler import SchedConfig, Scheduler
from .sse import encode_event

_MAX_BODY = 1 << 20      # 1 MiB request-body cap


def _json_default(o):
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


def _http_response(status: str, body: bytes, ctype: str = "application/json"
                   ) -> bytes:
    return (f"HTTP/1.1 {status}\r\n"
            f"Content-Type: {ctype}\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n").encode() + body


def _sse_headers() -> bytes:
    return (b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: text/event-stream\r\n"
            b"Cache-Control: no-cache\r\n"
            b"Connection: close\r\n\r\n")


class FrontDoor:
    """The streaming HTTP server that owns a ``PagedServer`` + ``Scheduler``.

    ``tokenize`` / ``detokenize`` translate between request-body strings
    and model tokens; they default to the repo's ``ByteTokenizer`` over the
    engine's vocab.  ``port=0`` binds an ephemeral port; the chosen one is
    printed as ``frontdoor listening on HOST:PORT`` (the smoke tests parse
    that line) and stored back on ``self.port``.
    """

    def __init__(self, engine, cfg: SchedConfig | None = None, *,
                 host: str = "127.0.0.1", port: int = 8080,
                 tokenize=None, detokenize=None):
        self.engine = engine
        self.scheduler = Scheduler(engine, cfg)
        self.host, self.port = host, port
        if tokenize is None or detokenize is None:
            from repro.data import ByteTokenizer
            tok = ByteTokenizer(engine.cfg.vocab)
            tokenize = tokenize or tok.encode
            detokenize = detokenize or (
                lambda ids: tok.decode(np.asarray(ids, np.int32)))
        self.tokenize, self.detokenize = tokenize, detokenize
        self._rids = itertools.count()
        self._lock = threading.Lock()        # scheduler + engine state
        self._watchers: dict[int, asyncio.Queue] = {}
        self._wake = threading.Event()       # driver: new work submitted
        self._stopping = threading.Event()
        self._loop: asyncio.AbstractEventLoop | None = None

    # -------------------------------------------------------- driver thread

    def _post(self, q: asyncio.Queue, item) -> None:
        self._loop.call_soon_threadsafe(q.put_nowait, item)

    def _drive(self) -> None:
        """The serving loop: tick the scheduler while there is work, sleep
        on the wake event while there isn't.  A crash here is fatal to the
        server (every open stream gets an ``error`` event first) — the
        engine's state cannot be trusted after an arbitrary exception."""
        self.engine.start_clock()
        try:
            while not self._stopping.is_set():
                with self._lock:
                    busy = self.scheduler.has_work()
                    finished = self.scheduler.tick() if busy else {}
                    done_watch = [(self._watchers.pop(rid, None), res)
                                  for rid, res in finished.items()]
                for q, res in done_watch:
                    if q is not None:
                        self._post(q, ("done", self._done_payload(res)))
                if not busy:
                    self._wake.wait(0.02)
                    self._wake.clear()
        except Exception:                                 # noqa: BLE001
            traceback.print_exc()
            with self._lock:
                watchers, self._watchers = dict(self._watchers), {}
            for q in watchers.values():
                self._post(q, ("error", {"error": "engine failure"}))
            self._stopping.set()

    def _done_payload(self, res) -> dict:
        toks = [int(t) for t in res.tokens]
        return {"rid": res.rid, "tokens": toks, "text": self.detokenize(toks),
                "n_tokens": len(toks), "ttft_s": float(res.ttft_s),
                "preemptions": int(res.preemptions), "tenant": res.tenant}

    # --------------------------------------------------------- HTTP parsing

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            request_line = await reader.readline()
            parts = request_line.decode("latin-1").split()
            if len(parts) < 2:
                return
            method, path = parts[0], parts[1]
            headers = {}
            while True:
                line = await reader.readline()
                if line in (b"\r\n", b"\n", b""):
                    break
                k, _, v = line.decode("latin-1").partition(":")
                headers[k.strip().lower()] = v.strip()
            clen = int(headers.get("content-length", 0) or 0)
            if clen > _MAX_BODY:
                writer.write(_http_response(
                    "413 Payload Too Large",
                    b'{"error": "request body too large"}'))
                return
            body = await reader.readexactly(clen) if clen else b""
            if method == "POST" and path == "/v1/generate":
                await self._generate(body, writer)
            elif method == "GET" and path == "/healthz":
                writer.write(_http_response("200 OK", b'{"ok": true}'))
            elif method == "GET" and path == "/v1/stats":
                writer.write(_http_response("200 OK", self._stats_body()))
            else:
                writer.write(_http_response(
                    "404 Not Found", b'{"error": "no such route"}'))
            await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            with contextlib.suppress(Exception):
                writer.close()
                await writer.wait_closed()

    def _stats_body(self) -> bytes:
        with self._lock:
            stats = dict(self.engine.finalize_stats())
            sched = dict(self.scheduler.stats)
            snap = {"engine": stats, "scheduler": sched,
                    "queued": self.scheduler.queued(),
                    "slo_throttled": self.scheduler.throttled,
                    "slo_last_p95_ms": self.scheduler.last_p95_ms}
        # the raw per-step lists are internal accounting, not API surface
        snap["engine"].pop("occupancy", None)
        snap["engine"].pop("decode_gap_s", None)
        return json.dumps(snap, default=_json_default).encode()

    # ----------------------------------------------------------- generation

    def _build_request(self, spec: dict, q: asyncio.Queue) -> Request:
        if "tokens" in spec:
            prompt = np.asarray(spec["tokens"], np.int32)
        elif "prompt" in spec:
            prompt = np.asarray(self.tokenize(str(spec["prompt"])), np.int32)
        else:
            raise ValueError('body needs "prompt" (string) or "tokens"')
        rid = next(self._rids)
        detok = self.detokenize

        def on_token(rid_, tok, t):
            self._post(q, ("token", {"rid": rid_, "token": tok,
                                     "text": detok([tok]), "t": t}))

        return Request(
            rid=rid, prompt=prompt, max_new=int(spec.get("max_new", 16)),
            eos=spec.get("eos"), arrival=self.engine.now(),
            tenant=str(spec.get("tenant", "default")),
            priority=int(spec.get("priority", 0)),
            deadline=spec.get("deadline_s"),
            on_token=on_token if spec.get("stream", True) else None)

    async def _generate(self, body: bytes,
                        writer: asyncio.StreamWriter) -> None:
        try:
            spec = json.loads(body or b"{}")
            if not isinstance(spec, dict):
                raise ValueError("body must be a JSON object")
            q: asyncio.Queue = asyncio.Queue()
            req = self._build_request(spec, q)
            with self._lock:
                # validates under the lock so a bad request 400s here
                # instead of crashing the driver thread
                self.scheduler.submit(req, weight=float(spec.get("weight",
                                                                 1.0)))
                self._watchers[req.rid] = q
        except (ValueError, TypeError, KeyError, json.JSONDecodeError) as e:
            writer.write(_http_response(
                "400 Bad Request",
                json.dumps({"error": str(e)}).encode()))
            return
        self._wake.set()
        streaming = bool(spec.get("stream", True))
        if streaming:
            writer.write(_sse_headers())
            await writer.drain()
        collected: dict | None = None
        try:
            while True:
                event, data = await q.get()
                if streaming:
                    writer.write(encode_event(event, data))
                    await writer.drain()
                if event in ("done", "error"):
                    collected = data
                    break
            if not streaming:
                status = ("200 OK" if "error" not in (collected or {})
                          else "500 Internal Server Error")
                writer.write(_http_response(
                    status, json.dumps(collected,
                                       default=_json_default).encode()))
                await writer.drain()
        except (ConnectionError, asyncio.CancelledError):
            # client went away mid-stream: stop burning pool capacity
            with self._lock:
                self._watchers.pop(req.rid, None)
                self.scheduler.cancel(req.rid)
            raise

    # -------------------------------------------------------------- running

    async def _main(self) -> None:
        self._loop = asyncio.get_running_loop()
        server = await asyncio.start_server(self._handle, self.host,
                                            self.port)
        self.port = server.sockets[0].getsockname()[1]
        print(f"frontdoor listening on {self.host}:{self.port}", flush=True)
        driver = threading.Thread(target=self._drive, daemon=True,
                                  name="frontdoor-driver")
        driver.start()
        stop = asyncio.Event()
        for sig in (signal.SIGINT, signal.SIGTERM):
            with contextlib.suppress(NotImplementedError, ValueError):
                self._loop.add_signal_handler(sig, stop.set)
        # a driver crash must also bring the listener down
        async def watch_driver():
            while driver.is_alive() and not self._stopping.is_set():
                await asyncio.sleep(0.1)
            stop.set()
        watcher = asyncio.ensure_future(watch_driver())
        try:
            await stop.wait()
        finally:
            self._stopping.set()
            self._wake.set()
            watcher.cancel()
            server.close()
            await server.wait_closed()
            driver.join(timeout=5.0)
            print("frontdoor shut down cleanly", flush=True)

    def serve_forever(self) -> None:
        """Run until SIGINT/SIGTERM (clean shutdown) or driver crash."""
        try:
            asyncio.run(self._main())
        except KeyboardInterrupt:
            pass
