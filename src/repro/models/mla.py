"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV state is compressed to a per-token latent c_kv (kv_lora dims) plus a
shared rotary key (qk_rope dims).  Prefill/train expands K/V and runs the
chunked flash path; decode uses the absorbed form (W_uk folded into the
query, W_uv applied after the softmax) so the cache stays
(B, S, kv_lora + qk_rope) — the whole point of MLA.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import attention as attn
from .common import LinearCtx, apply_rope, linear, rms_norm


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MLACache:
    c_kv: jax.Array    # (B, cap, kv_lora)
    k_rope: jax.Array  # (B, cap, qk_rope)

    @staticmethod
    def init(b: int, cap: int, kv_lora: int, qk_rope: int, dtype=jnp.float32):
        return MLACache(c_kv=jnp.zeros((b, cap, kv_lora), dtype),
                        k_rope=jnp.zeros((b, cap, qk_rope), dtype))


def _project_q(p: dict, x: jax.Array, mcfg, positions, ctx, name):
    cq = rms_norm(linear(p["wq_a"], x, ctx, f"{name}.wq_a"), p["q_norm"])
    q = linear(p["wq_b"], cq, ctx, f"{name}.wq_b")
    b, s = x.shape[:2]
    q = q.reshape(b, s, mcfg.n_heads, mcfg.qk_nope + mcfg.qk_rope)
    q_nope, q_rope = q[..., :mcfg.qk_nope], q[..., mcfg.qk_nope:]
    q_rope = apply_rope(q_rope, positions)
    return q_nope, q_rope


def _project_kv_latent(p: dict, x: jax.Array, mcfg, positions, ctx, name):
    kv_a = linear(p["wkv_a"], x, ctx, f"{name}.wkv_a")
    c_kv = rms_norm(kv_a[..., : mcfg.kv_lora], p["kv_norm"])
    k_rope = kv_a[..., mcfg.kv_lora:]
    b, s = x.shape[:2]
    k_rope = apply_rope(k_rope.reshape(b, s, 1, mcfg.qk_rope), positions)[:, :, 0]
    return c_kv, k_rope


def mla_full(p: dict, x: jax.Array, mcfg, positions: jax.Array,
             ctx: LinearCtx | None = None, name: str = "mla",
             remat_chunks: bool = False) -> jax.Array:
    """Train / prefill path: expand K,V, chunked flash attention."""
    b, s, _ = x.shape
    h, dn, dr, dv = mcfg.n_heads, mcfg.qk_nope, mcfg.qk_rope, mcfg.v_head
    q_nope, q_rope = _project_q(p, x, mcfg, positions, ctx, name)
    c_kv, k_rope = _project_kv_latent(p, x, mcfg, positions, ctx, name)
    kv = linear(p["wkv_b"], c_kv, ctx, f"{name}.wkv_b").reshape(b, s, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (b, s, h, dr))], axis=-1)
    out = attn.flash_attention(q, k, v, causal=True,
                               remat_chunks=remat_chunks)
    out = out.reshape(b, s, h * dv)
    return linear(p["wo"], out, ctx, f"{name}.wo")


def mla_decode_paged(p: dict, x: jax.Array, mcfg, cache: MLACache,
                     pos: jax.Array, active: jax.Array,
                     ctx: LinearCtx | None = None, name: str = "mla"):
    """Slot-indexed absorbed decode for the paged serving engine.

    ``cache`` fields are per-slot arrays (S, cap, ...) — one row per engine
    slot, linear (non-ring) layout.  ``pos`` (S,) is each slot's token count
    before this step; ``active`` (S,) masks slots whose write must be a no-op
    (their row is rewritten with its own current value) so the step can run
    with a fixed slot count while the batch composition churns.
    """
    b = x.shape[0]
    h, dn, dr, dv = mcfg.n_heads, mcfg.qk_nope, mcfg.qk_rope, mcfg.v_head
    positions = pos[:, None].astype(jnp.int32)                  # (S, 1)
    q_nope, q_rope = _project_q(p, x, mcfg, positions, ctx, name)
    c_new, kr_new = _project_kv_latent(p, x, mcfg, positions, ctx, name)
    cap = cache.c_kv.shape[1]
    rows = jnp.arange(b, dtype=jnp.int32)
    slot_pos = jnp.minimum(pos, cap - 1).astype(jnp.int32)
    cd, rd = cache.c_kv.dtype, cache.k_rope.dtype
    c_write = jnp.where(active[:, None], c_new[:, 0].astype(cd),
                        cache.c_kv[rows, slot_pos])
    kr_write = jnp.where(active[:, None], kr_new[:, 0].astype(rd),
                         cache.k_rope[rows, slot_pos])
    cache = MLACache(c_kv=cache.c_kv.at[rows, slot_pos].set(c_write),
                     k_rope=cache.k_rope.at[rows, slot_pos].set(kr_write))
    w_b = p["wkv_b"].reshape(mcfg.kv_lora, h, dn + dv)
    w_uk, w_uv = w_b[..., :dn], w_b[..., dn:]
    qc = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(cd),
                    w_uk.astype(cd), preferred_element_type=jnp.float32)
    s = jnp.einsum("bhl,bsl->bhs", qc.astype(cd), cache.c_kv,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(cd),
                       cache.k_rope, preferred_element_type=jnp.float32)
    s = s * (dn + dr) ** -0.5
    valid = (jnp.arange(cap, dtype=jnp.int32)[None, :]
             < jnp.minimum(pos + 1, cap)[:, None])
    s = jnp.where(valid[:, None, :], s, attn.NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bhs,bsl->bhl", probs.astype(cd), cache.c_kv,
                       preferred_element_type=jnp.float32)
    out = jnp.einsum("bhl,lhd->bhd", ctx_c.astype(cd), w_uv.astype(cd),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * dv).astype(x.dtype)
    return linear(p["wo"], out, ctx, f"{name}.wo"), cache


def mla_prefill_chunk(p: dict, x: jax.Array, mcfg, cache: MLACache,
                      pos0: jax.Array, slot: jax.Array,
                      ctx: LinearCtx | None = None, name: str = "mla"):
    """Chunked-prefill continuation for one engine slot, absorbed form.

    x (1, C, d) is the prompt chunk starting at absolute position ``pos0``;
    the chunk's latents are appended to the slot's row (linear layout, fresh
    positions) and the chunk attends causally over everything up to itself.
    """
    b, c, _ = x.shape
    h, dn, dr, dv = mcfg.n_heads, mcfg.qk_nope, mcfg.qk_rope, mcfg.v_head
    positions = (pos0 + jnp.arange(c, dtype=jnp.int32))[None]   # (1, C)
    q_nope, q_rope = _project_q(p, x, mcfg, positions, ctx, name)
    c_new, kr_new = _project_kv_latent(p, x, mcfg, positions, ctx, name)
    cap = cache.c_kv.shape[1]
    row_c = jax.lax.dynamic_update_slice(
        cache.c_kv[slot], c_new[0].astype(cache.c_kv.dtype), (pos0, 0))
    row_kr = jax.lax.dynamic_update_slice(
        cache.k_rope[slot], kr_new[0].astype(cache.k_rope.dtype), (pos0, 0))
    cache = MLACache(c_kv=cache.c_kv.at[slot].set(row_c),
                     k_rope=cache.k_rope.at[slot].set(row_kr))
    w_b = p["wkv_b"].reshape(mcfg.kv_lora, h, dn + dv)
    w_uk, w_uv = w_b[..., :dn], w_b[..., dn:]
    cd = cache.c_kv.dtype
    qc = jnp.einsum("bchd,lhd->bchl", q_nope.astype(cd), w_uk.astype(cd),
                    preferred_element_type=jnp.float32)
    s = jnp.einsum("bchl,sl->bchs", qc.astype(cd), row_c,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bchr,sr->bchs", q_rope.astype(cd), row_kr,
                       preferred_element_type=jnp.float32)
    s = s * (dn + dr) ** -0.5
    valid = (jnp.arange(cap, dtype=jnp.int32)[None, None, :]
             <= positions[..., None])                           # (1, C, cap)
    s = jnp.where(valid[:, :, None, :], s, attn.NEG_INF)        # (1, C, h, cap)
    probs = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bchs,sl->bchl", probs.astype(cd), row_c,
                       preferred_element_type=jnp.float32)
    out = jnp.einsum("bchl,lhd->bchd", ctx_c.astype(cd), w_uv.astype(cd),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, c, h * dv).astype(x.dtype)
    return linear(p["wo"], out, ctx, f"{name}.wo"), cache


def mla_decode(p: dict, x: jax.Array, mcfg, cache: MLACache, pos: jax.Array,
               ctx: LinearCtx | None = None, name: str = "mla"):
    """Absorbed decode: scores/context in latent space, cache stays compressed."""
    b = x.shape[0]
    h, dn, dr, dv = mcfg.n_heads, mcfg.qk_nope, mcfg.qk_rope, mcfg.v_head
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
    q_nope, q_rope = _project_q(p, x, mcfg, positions, ctx, name)   # (b,1,h,*)
    c_new, kr_new = _project_kv_latent(p, x, mcfg, positions, ctx, name)
    cap = cache.c_kv.shape[1]
    slot = (pos % cap).astype(jnp.int32)
    cache = MLACache(
        c_kv=jax.lax.dynamic_update_slice(cache.c_kv,
                                          c_new.astype(cache.c_kv.dtype),
                                          (0, slot, 0)),
        k_rope=jax.lax.dynamic_update_slice(cache.k_rope,
                                            kr_new.astype(cache.k_rope.dtype),
                                            (0, slot, 0)))
    w_b = p["wkv_b"].reshape(mcfg.kv_lora, h, dn + dv)
    w_uk, w_uv = w_b[..., :dn], w_b[..., dn:]
    # contract against the caches in their storage dtype (f32 casts would
    # round-trip the compressed cache through HBM per layer — §Perf)
    cdtype = cache.c_kv.dtype
    qc = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(cdtype),
                    w_uk.astype(cdtype),
                    preferred_element_type=jnp.float32)             # (b,h,lora)
    s = jnp.einsum("bhl,bsl->bhs", qc.astype(cdtype), cache.c_kv,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(cdtype),
                       cache.k_rope, preferred_element_type=jnp.float32)
    s = s * (dn + dr) ** -0.5
    valid = jnp.arange(cap, dtype=jnp.int32)[None, :] < jnp.minimum(pos + 1, cap)
    s = jnp.where(valid[:, None, :], s, attn.NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bhs,bsl->bhl", probs.astype(cdtype), cache.c_kv,
                       preferred_element_type=jnp.float32)
    out = jnp.einsum("bhl,lhd->bhd", ctx_c.astype(cdtype),
                     w_uv.astype(cdtype),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * dv).astype(x.dtype)
    return linear(p["wo"], out, ctx, f"{name}.wo"), cache
