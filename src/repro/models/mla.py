"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV state is compressed to a per-token latent c_kv (kv_lora dims) plus a
shared rotary key (qk_rope dims).  Prefill/train expands K/V and runs the
chunked flash path; decode uses the absorbed form (W_uk folded into the
query, W_uv applied after the softmax) so the cache stays
(B, S, kv_lora + qk_rope) — the whole point of MLA.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import attention as attn
from .common import LinearCtx, apply_rope, linear, rms_norm


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MLACache:
    c_kv: jax.Array    # (B, cap, kv_lora)
    k_rope: jax.Array  # (B, cap, qk_rope)

    @staticmethod
    def init(b: int, cap: int, kv_lora: int, qk_rope: int, dtype=jnp.float32):
        return MLACache(c_kv=jnp.zeros((b, cap, kv_lora), dtype),
                        k_rope=jnp.zeros((b, cap, qk_rope), dtype))


def _project_q(p: dict, x: jax.Array, mcfg, positions, ctx, name):
    cq = rms_norm(linear(p["wq_a"], x, ctx, f"{name}.wq_a"), p["q_norm"])
    q = linear(p["wq_b"], cq, ctx, f"{name}.wq_b")
    b, s = x.shape[:2]
    q = q.reshape(b, s, mcfg.n_heads, mcfg.qk_nope + mcfg.qk_rope)
    q_nope, q_rope = q[..., :mcfg.qk_nope], q[..., mcfg.qk_nope:]
    q_rope = apply_rope(q_rope, positions)
    return q_nope, q_rope


def _project_kv_latent(p: dict, x: jax.Array, mcfg, positions, ctx, name):
    kv_a = linear(p["wkv_a"], x, ctx, f"{name}.wkv_a")
    c_kv = rms_norm(kv_a[..., : mcfg.kv_lora], p["kv_norm"])
    k_rope = kv_a[..., mcfg.kv_lora:]
    b, s = x.shape[:2]
    k_rope = apply_rope(k_rope.reshape(b, s, 1, mcfg.qk_rope), positions)[:, :, 0]
    return c_kv, k_rope


def mla_full(p: dict, x: jax.Array, mcfg, positions: jax.Array,
             ctx: LinearCtx | None = None, name: str = "mla",
             remat_chunks: bool = False) -> jax.Array:
    """Train / prefill path: expand K,V, chunked flash attention."""
    b, s, _ = x.shape
    h, dn, dr, dv = mcfg.n_heads, mcfg.qk_nope, mcfg.qk_rope, mcfg.v_head
    q_nope, q_rope = _project_q(p, x, mcfg, positions, ctx, name)
    c_kv, k_rope = _project_kv_latent(p, x, mcfg, positions, ctx, name)
    kv = linear(p["wkv_b"], c_kv, ctx, f"{name}.wkv_b").reshape(b, s, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (b, s, h, dr))], axis=-1)
    out = attn.flash_attention(q, k, v, causal=True,
                               remat_chunks=remat_chunks)
    out = out.reshape(b, s, h * dv)
    return linear(p["wo"], out, ctx, f"{name}.wo")


def mla_decode(p: dict, x: jax.Array, mcfg, cache: MLACache, pos: jax.Array,
               ctx: LinearCtx | None = None, name: str = "mla"):
    """Absorbed decode: scores/context in latent space, cache stays compressed."""
    b = x.shape[0]
    h, dn, dr, dv = mcfg.n_heads, mcfg.qk_nope, mcfg.qk_rope, mcfg.v_head
    positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
    q_nope, q_rope = _project_q(p, x, mcfg, positions, ctx, name)   # (b,1,h,*)
    c_new, kr_new = _project_kv_latent(p, x, mcfg, positions, ctx, name)
    cap = cache.c_kv.shape[1]
    slot = (pos % cap).astype(jnp.int32)
    cache = MLACache(
        c_kv=jax.lax.dynamic_update_slice(cache.c_kv,
                                          c_new.astype(cache.c_kv.dtype),
                                          (0, slot, 0)),
        k_rope=jax.lax.dynamic_update_slice(cache.k_rope,
                                            kr_new.astype(cache.k_rope.dtype),
                                            (0, slot, 0)))
    w_b = p["wkv_b"].reshape(mcfg.kv_lora, h, dn + dv)
    w_uk, w_uv = w_b[..., :dn], w_b[..., dn:]
    # contract against the caches in their storage dtype (f32 casts would
    # round-trip the compressed cache through HBM per layer — §Perf)
    cdtype = cache.c_kv.dtype
    qc = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(cdtype),
                    w_uk.astype(cdtype),
                    preferred_element_type=jnp.float32)             # (b,h,lora)
    s = jnp.einsum("bhl,bsl->bhs", qc.astype(cdtype), cache.c_kv,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(cdtype),
                       cache.k_rope, preferred_element_type=jnp.float32)
    s = s * (dn + dr) ** -0.5
    valid = jnp.arange(cap, dtype=jnp.int32)[None, :] < jnp.minimum(pos + 1, cap)
    s = jnp.where(valid[:, None, :], s, attn.NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bhs,bsl->bhl", probs.astype(cdtype), cache.c_kv,
                       preferred_element_type=jnp.float32)
    out = jnp.einsum("bhl,lhd->bhd", ctx_c.astype(cdtype),
                     w_uv.astype(cdtype),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * dv).astype(x.dtype)
    return linear(p["wo"], out, ctx, f"{name}.wo"), cache
