"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block = (gate branch: GeLU(W_g x)) * (recurrence branch: RG-LRU(conv1d(W_x x)))
-> W_o.  The RG-LRU is a gated diagonal linear recurrence

    r_t = sigmoid(BD_a xc_t);  i_t = sigmoid(BD_x xc_t)
    a_t = exp(c * r_t * log sigmoid(Lambda))          (per channel, in (0,1))
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * xc_t)

computed over a sequence with jax.lax.associative_scan (parallel prefix), and
as a single fused step at decode.  Gate projections are block-diagonal with
one block per head, as in the reference implementation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import LinearCtx, linear

RGLRU_C = 8.0
CONV_WIDTH = 4


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RGLRUState:
    h: jax.Array     # (B, dr) recurrent state
    conv: jax.Array  # (B, CONV_WIDTH-1, dr) trailing conv inputs

    @staticmethod
    def init(b: int, dr: int, dtype=jnp.float32):
        return RGLRUState(h=jnp.zeros((b, dr), jnp.float32),
                          conv=jnp.zeros((b, CONV_WIDTH - 1, dr), dtype))


def _block_diag(w: jax.Array, x: jax.Array) -> jax.Array:
    """x (..., dr) @ block-diagonal w (nb, dr/nb, dr/nb) -> (..., dr)."""
    nb, bs, _ = w.shape
    xb = x.reshape(*x.shape[:-1], nb, bs)
    yb = jnp.einsum("...nb,nbc->...nc", xb, w.astype(x.dtype))
    return yb.reshape(*x.shape)


def _gates(p: dict, xc: jax.Array):
    r = jax.nn.sigmoid(_block_diag(p["wa"], xc) + p["ba"])
    i = jax.nn.sigmoid(_block_diag(p["wx"], xc) + p["bx"])
    log_a = (RGLRU_C * r.astype(jnp.float32)
             * jax.nn.log_sigmoid(p["lambda"].astype(jnp.float32)))
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i.astype(jnp.float32) * xc.astype(jnp.float32))
    return a, gated_in


def _conv1d_seq(p: dict, h: jax.Array,
                hist: jax.Array | None = None) -> jax.Array:
    """Causal per-channel conv, width CONV_WIDTH, over (B, S, dr).

    ``hist`` (B, CONV_WIDTH-1, dr): trailing inputs from a previous chunk
    (mid-sequence continuation); zeros when absent."""
    w = p["conv_w"].astype(h.dtype)                       # (W, dr)
    if hist is None:
        acc = h * w[-1]
        for i in range(1, CONV_WIDTH):
            acc = acc + jnp.pad(h, ((0, 0), (i, 0), (0, 0)))[:, :-i] * w[-1 - i]
        return acc + p["conv_b"].astype(h.dtype)
    s = h.shape[1]
    full = jnp.concatenate([hist.astype(h.dtype), h], axis=1)  # (B, W-1+S, dr)
    acc = h * w[-1]
    for i in range(1, CONV_WIDTH):
        acc = acc + full[:, CONV_WIDTH - 1 - i: CONV_WIDTH - 1 - i + s] * w[-1 - i]
    return acc + p["conv_b"].astype(h.dtype)


def rglru_block(p: dict, x: jax.Array, ctx: LinearCtx | None = None,
                name: str = "rglru", return_state: bool = False,
                state: RGLRUState | None = None):
    """Sequence mode: x (B, S, d) -> (B, S, d) [, RGLRUState].

    ``state`` resumes mid-sequence (chunked prefill): the recurrence starts
    from ``state.h`` and the causal conv sees ``state.conv`` history."""
    g = jax.nn.gelu(linear(p["wg"], x, ctx, f"{name}.wg"))
    hx = linear(p["wi"], x, ctx, f"{name}.wi")
    xc = _conv1d_seq(p, hx, None if state is None else state.conv)
    a, b = _gates(p, xc)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if state is not None:
        h = h + a_cum * state.h.astype(h.dtype)[:, None]
    out = (g.astype(jnp.float32) * h).astype(x.dtype)
    y = linear(p["wo"], out, ctx, f"{name}.wo")
    if return_state:
        if state is None:
            w = rglrumod_conv_tail(hx)
        else:
            w = jnp.concatenate([state.conv.astype(hx.dtype), hx],
                                axis=1)[:, -(CONV_WIDTH - 1):]
        return y, RGLRUState(h=h[:, -1], conv=w)
    return y


def rglrumod_conv_tail(hx: jax.Array) -> jax.Array:
    """Last CONV_WIDTH-1 conv inputs (left-padded for short sequences)."""
    b, s, dr = hx.shape
    need = CONV_WIDTH - 1
    if s >= need:
        return hx[:, s - need:]
    pad = jnp.zeros((b, need - s, dr), hx.dtype)
    return jnp.concatenate([pad, hx], axis=1)


def rglru_decode(p: dict, x: jax.Array, state: RGLRUState,
                 ctx: LinearCtx | None = None, name: str = "rglru"):
    """One step: x (B, d) -> (out (B, d), new state)."""
    g = jax.nn.gelu(linear(p["wg"], x, ctx, f"{name}.wg"))
    hx = linear(p["wi"], x, ctx, f"{name}.wi")             # (B, dr)
    w = p["conv_w"].astype(hx.dtype)
    hist = jnp.concatenate([state.conv, hx[:, None, :]], axis=1)  # (B, W, dr)
    xc = jnp.einsum("bwd,wd->bd", hist, w) + p["conv_b"].astype(hx.dtype)
    a, b = _gates(p, xc)
    h_new = a * state.h + b
    out = (g.astype(jnp.float32) * h_new).astype(x.dtype)
    out = linear(p["wo"], out, ctx, f"{name}.wo")
    return out, RGLRUState(h=h_new, conv=hist[:, 1:])
