"""Attention: chunked (flash-style) GQA with causal/sliding windows, KV caches,
and DeepSeek-V2 MLA (compressed-KV) — pure JAX, SPMD-friendly.

The chunked kernel is an online-softmax scan over KV blocks (queries chunked
too), so the S x S score matrix is never materialized: prefill_32k fits, and
under GSPMD a sequence-sharded cache turns the softmax reductions into
all-reduces (flash-decoding style partial-softmax combine, inserted by XLA).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ------------------------------------------------------------ chunked flash


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int | jax.Array = 0,
                    q_chunk: int = 1024, k_chunk: int = 1024,
                    remat_chunks: bool = False,
                    expand_kv: bool = False) -> jax.Array:
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd), H = KV*G -> (B,Sq,H,hd).

    ``window``: causal sliding window (attend to the last ``window`` keys).
    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    ``remat_chunks``: checkpoint the KV-chunk step so autodiff recomputes the
    (cq, ck) probability block in the backward instead of stacking it as a
    scan residual — the FlashAttention backward strategy; turns O(S^2)
    residual HBM traffic into O(S^2) recompute flops (EXPERIMENTS.md §Perf).
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    if expand_kv and kv != h:
        # GQA reshape (h -> kv x g) defeats head sharding when h % mesh != 0
        # on the grouped layout; expanding K/V to h heads costs g x K/V bytes
        # but keeps the einsums shardable on the flat head axis (§Perf B2)
        g_rep = h // kv
        k = jnp.repeat(k, g_rep, axis=2)
        v = jnp.repeat(v, g_rep, axis=2)
        kv = h
    from repro.runtime.actsharding import shard_named
    q = shard_named(q, "qkv")
    k = shard_named(k, "qkv")
    v = shard_named(v, "qkv")
    dv = v.shape[-1]                # may differ from hd (MLA)
    g = h // kv
    scale = hd ** -0.5
    cq, ck = min(q_chunk, sq), min(k_chunk, sk)
    nq, nk = -(-sq // cq), -(-sk // ck)
    sq_p, sk_p = nq * cq, nk * ck
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    qp = qp.reshape(b, nq, cq, kv, g, hd)
    kp = kp.reshape(b, nk, ck, kv, hd)
    vp = vp.reshape(b, nk, ck, kv, dv)
    qpos0 = jnp.asarray(q_offset, jnp.int32)

    def q_block(qi):
        qc = qp[:, qi].astype(jnp.float32) * scale          # (b,cq,kv,g,hd)
        qpos = qpos0 + qi * cq + jnp.arange(cq, dtype=jnp.int32)

        def kv_step(carry, ki):
            m, l, acc = carry
            kc = kp[:, ki].astype(jnp.float32)              # (b,ck,kv,hd)
            vc = vp[:, ki].astype(jnp.float32)
            kpos = ki * ck + jnp.arange(ck, dtype=jnp.int32)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc)     # (b,kv,g,cq,ck)
            mask = kpos[None, :] <= (qpos[:, None] if causal else jnp.int32(2**30))
            mask &= kpos[None, :] < sk                       # key padding
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vc)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, cq, dv), jnp.float32)
        step = jax.checkpoint(kv_step) if remat_chunks else kv_step
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                      jnp.arange(nk, dtype=jnp.int32))
        out = acc / jnp.maximum(l, 1e-30)[..., None]        # (b,kv,g,cq,hd)
        return jnp.moveaxis(out, 3, 1)                      # (b,cq,kv,g,hd)

    out = jax.lax.map(q_block, jnp.arange(nq, dtype=jnp.int32))  # (nq,b,cq,kv,g,dv)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq_p, kv, g, dv)[:, :sq]
    return out.reshape(b, sq, h, dv).astype(q.dtype)


# ------------------------------------------------------------------ caches


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """Ring-buffered when capacity < full context (sliding-window archs)."""
    k: jax.Array          # (B, cap, KV, hd)
    v: jax.Array          # (B, cap, KV, hd)

    @staticmethod
    def init(b: int, cap: int, kv: int, hd: int, dtype=jnp.float32) -> "KVCache":
        return KVCache(k=jnp.zeros((b, cap, kv, hd), dtype),
                       v=jnp.zeros((b, cap, kv, hd), dtype))


def cache_insert(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array) -> KVCache:
    """Insert one step (B,1,KV,hd) at ring slot pos % cap."""
    cap = cache.k.shape[1]
    slot = (pos % cap).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, slot, 0, 0))
    return KVCache(k=k, v=v)


def decode_attention(q: jax.Array, cache: KVCache, pos: jax.Array) -> jax.Array:
    """One-token attention over the cache. q (B,1,H,hd) -> (B,1,H,hd).

    ``pos``: current absolute position (number of tokens already inserted,
    including this one).  With a ring buffer every slot written so far is a
    valid window member (softmax is permutation-invariant), so validity is
    just slot_index < pos for the full-cache case and "written" for rings.
    """
    b, _, h, hd = q.shape
    cap, kv = cache.k.shape[1], cache.k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    # keep the cache in its storage dtype — casting it to f32 would round-trip
    # the full cache through HBM every layer (§Perf iteration); accumulate the
    # contractions in f32 instead.
    qf = (q.astype(jnp.float32) * scale).astype(cache.k.dtype)
    qf = qf.reshape(b, kv, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, cache.k,
                   preferred_element_type=jnp.float32)       # (b,kv,g,cap)
    valid = jnp.arange(cap, dtype=jnp.int32)[None, :] < jnp.minimum(pos, cap)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(cache.v.dtype), cache.v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)
