"""Attention: chunked (flash-style) GQA with causal/sliding windows, KV caches,
and DeepSeek-V2 MLA (compressed-KV) — pure JAX, SPMD-friendly.

The chunked kernel is an online-softmax scan over KV blocks (queries chunked
too), so the S x S score matrix is never materialized: prefill_32k fits, and
under GSPMD a sequence-sharded cache turns the softmax reductions into
all-reduces (flash-decoding style partial-softmax combine, inserted by XLA).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ------------------------------------------------------------ chunked flash


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int | jax.Array = 0,
                    q_chunk: int = 1024, k_chunk: int = 1024,
                    remat_chunks: bool = False,
                    expand_kv: bool = False) -> jax.Array:
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd), H = KV*G -> (B,Sq,H,hd).

    ``window``: causal sliding window (attend to the last ``window`` keys).
    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    ``remat_chunks``: checkpoint the KV-chunk step so autodiff recomputes the
    (cq, ck) probability block in the backward instead of stacking it as a
    scan residual — the FlashAttention backward strategy; turns O(S^2)
    residual HBM traffic into O(S^2) recompute flops (EXPERIMENTS.md §Perf).
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    if expand_kv and kv != h:
        # GQA reshape (h -> kv x g) defeats head sharding when h % mesh != 0
        # on the grouped layout; expanding K/V to h heads costs g x K/V bytes
        # but keeps the einsums shardable on the flat head axis (§Perf B2)
        g_rep = h // kv
        k = jnp.repeat(k, g_rep, axis=2)
        v = jnp.repeat(v, g_rep, axis=2)
        kv = h
    from repro.runtime.actsharding import shard_named
    q = shard_named(q, "qkv")
    k = shard_named(k, "qkv")
    v = shard_named(v, "qkv")
    dv = v.shape[-1]                # may differ from hd (MLA)
    g = h // kv
    scale = hd ** -0.5
    cq, ck = min(q_chunk, sq), min(k_chunk, sk)
    nq, nk = -(-sq // cq), -(-sk // ck)
    sq_p, sk_p = nq * cq, nk * ck
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    qp = qp.reshape(b, nq, cq, kv, g, hd)
    kp = kp.reshape(b, nk, ck, kv, hd)
    vp = vp.reshape(b, nk, ck, kv, dv)
    qpos0 = jnp.asarray(q_offset, jnp.int32)

    def q_block(qi):
        qc = qp[:, qi].astype(jnp.float32) * scale          # (b,cq,kv,g,hd)
        qpos = qpos0 + qi * cq + jnp.arange(cq, dtype=jnp.int32)

        def kv_step(carry, ki):
            m, l, acc = carry
            kc = kp[:, ki].astype(jnp.float32)              # (b,ck,kv,hd)
            vc = vp[:, ki].astype(jnp.float32)
            kpos = ki * ck + jnp.arange(ck, dtype=jnp.int32)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc)     # (b,kv,g,cq,ck)
            mask = kpos[None, :] <= (qpos[:, None] if causal else jnp.int32(2**30))
            mask &= kpos[None, :] < sk                       # key padding
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vc)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, cq, dv), jnp.float32)
        step = jax.checkpoint(kv_step) if remat_chunks else kv_step
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                      jnp.arange(nk, dtype=jnp.int32))
        out = acc / jnp.maximum(l, 1e-30)[..., None]        # (b,kv,g,cq,hd)
        return jnp.moveaxis(out, 3, 1)                      # (b,cq,kv,g,hd)

    out = jax.lax.map(q_block, jnp.arange(nq, dtype=jnp.int32))  # (nq,b,cq,kv,g,dv)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq_p, kv, g, dv)[:, :sq]
    return out.reshape(b, sq, h, dv).astype(q.dtype)


# ------------------------------------------------------------------ caches


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """Ring-buffered when capacity < full context (sliding-window archs)."""
    k: jax.Array          # (B, cap, KV, hd)
    v: jax.Array          # (B, cap, KV, hd)

    @staticmethod
    def init(b: int, cap: int, kv: int, hd: int, dtype=jnp.float32) -> "KVCache":
        return KVCache(k=jnp.zeros((b, cap, kv, hd), dtype),
                       v=jnp.zeros((b, cap, kv, hd), dtype))


def cache_insert(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array) -> KVCache:
    """Insert one step (B,1,KV,hd) at ring slot pos % cap."""
    cap = cache.k.shape[1]
    slot = (pos % cap).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, slot, 0, 0))
    return KVCache(k=k, v=v)


# ------------------------------------------------------------ paged caches
#
# The serving engine (repro/serve) stores K/V in a fixed arena of
# (num_blocks, block_size, KV, hd) blocks shared by all requests; each request
# owns a row of a block table mapping logical block -> physical block.
# Logical token index for a request at absolute position p is p % ring_cap,
# where ring_cap = allocated_blocks * block_size: full-context requests get
# ring_cap >= total length (the ring never wraps, indices are linear), and
# sliding-window requests get ring_cap = ceil(window / block_size) * block_size
# so old blocks are reused in place (ring-window reuse).  Physical block 0 is
# reserved as the null block: unallocated table entries and writes from
# inactive slots land there and are never read as valid.


def paged_gather_kv(arena: jax.Array, block_table: jax.Array) -> jax.Array:
    """arena (N, bs, ...), block_table (B, MB) int32 -> (B, MB*bs, ...)."""
    g = arena[block_table]                       # (B, MB, bs, ...)
    b, mb, bs = g.shape[:3]
    return g.reshape(b, mb * bs, *arena.shape[2:])


def paged_slot_positions(pos: jax.Array, ring_cap: jax.Array,
                         length: int) -> jax.Array:
    """Absolute position stored in each logical slot, -1 if never written.

    ``pos`` (B,): tokens inserted so far (including the current one);
    ``ring_cap`` (B,): per-request ring capacity; ``length``: gathered slot
    count (>= ring_cap; slots past ring_cap are unallocated padding).
    Slot s holds the largest p <= pos-1 with p % ring_cap == s.
    """
    idx = jnp.arange(length, dtype=jnp.int32)[None, :]
    last = (pos - 1)[:, None]
    c = ring_cap[:, None]
    stored = last - ((last - idx) % c)
    return jnp.where((idx < c) & (stored >= 0), stored, -1)


def paged_write_indices(pos: jax.Array, ring_cap: jax.Array,
                        block_table: jax.Array, block_size: int,
                        active: jax.Array | None = None):
    """(physical block, in-block offset) for writing position ``pos``.

    pos/ring_cap (B,) or scalar with block_table (B, MB) or (MB,).  Inactive
    slots are redirected to the null block 0 so one scatter serves the whole
    batch without conditionals (active requests always own disjoint blocks,
    so the scatter never has conflicting updates on real blocks).
    """
    li = (pos % ring_cap).astype(jnp.int32)
    off = li % block_size
    if block_table.ndim == 1:                       # single request row
        pb = block_table[li // block_size]
    else:
        b = block_table.shape[0]
        pb = block_table[jnp.arange(b, dtype=jnp.int32), li // block_size]
    if active is not None:
        pb = jnp.where(active, pb, 0)
        off = jnp.where(active, off, 0)
    return pb, off


def paged_multi_write_indices(positions: jax.Array, ring_cap: jax.Array,
                              block_tables: jax.Array, block_size: int,
                              write_mask: jax.Array | None = None):
    """(physical block, in-block offset) for writing a span of positions.

    The multi-token sibling of ``paged_write_indices``, used by the
    speculative verify / draft catch-up steps: ``positions`` (B, W) are each
    slot's absolute positions, ``ring_cap`` (B,) the per-slot ring
    capacities, ``block_tables`` (B, MB) the per-slot tables.  Positions
    whose ``write_mask`` (B, W) entry is False — inactive slots, or a
    catch-up position whose KV is already valid (rewriting it could perturb
    a shared prefix-cache block) — are redirected to the null block 0, so
    one fixed-shape scatter serves every slot regardless of churn.
    """
    li = (positions % ring_cap[:, None]).astype(jnp.int32)
    off = li % block_size
    pb = jnp.take_along_axis(block_tables, li // block_size, axis=1)
    if write_mask is not None:
        pb = jnp.where(write_mask, pb, 0)
        off = jnp.where(write_mask, off, 0)
    return pb, off


def paged_decode_attention(q: jax.Array, k_arena: jax.Array,
                           v_arena: jax.Array, block_table: jax.Array,
                           pos: jax.Array, ring_cap: jax.Array, *,
                           window: Optional[int] = None) -> jax.Array:
    """One-token attention over the paged arena.

    q (B,1,H,hd); arenas (N, bs, KV, hd); block_table (B, MB); pos (B,) =
    tokens inserted including the current one (whose K/V must already be in
    the arena); ring_cap (B,) per-request ring capacity.  Equivalent to
    ``decode_attention`` on a dense per-request cache (window masking is
    exact even when ring_cap is rounded up to a block multiple, because
    validity is computed from each slot's stored absolute position rather
    than from raw slot age).  Dispatches through
    ``kernels.paged_attention.ops`` — the Pallas flash-decode kernel reads
    arena blocks in place via the block table (DESIGN.md §10); the dense
    gather reference is the off-TPU default.
    """
    from repro.kernels.paged_attention import ops as pops  # late: no cycle
    return pops.paged_attention(q, k_arena, v_arena, block_table, pos,
                                ring_cap, window=window)


def paged_prefill_attention(q: jax.Array, k_hist: jax.Array, v_hist: jax.Array,
                            hist_pos: jax.Array, k_new: jax.Array,
                            v_new: jax.Array, q_pos: jax.Array, *,
                            window: Optional[int] = None) -> jax.Array:
    """Chunked-prefill attention: chunk queries over gathered history + chunk.

    q (B,C,H,hd); k_hist/v_hist (B,L,KV,hd) gathered from the arena with
    stored positions ``hist_pos`` (B,L) (-1 = invalid); k_new/v_new (B,C,KV,hd)
    are this chunk's keys at absolute positions ``q_pos`` (B,C).  Causal and
    sliding-window masks are evaluated on true absolute positions, so the
    result matches a full flash prefill restricted to these queries.
    """
    b, c, h, hd = q.shape
    kv = k_hist.shape[2]
    g = h // kv
    scale = hd ** -0.5
    k_all = jnp.concatenate([k_hist, k_new.astype(k_hist.dtype)], axis=1)
    v_all = jnp.concatenate([v_hist, v_new.astype(v_hist.dtype)], axis=1)
    kpos = jnp.concatenate([hist_pos, q_pos], axis=1)          # (B, L+C)
    qf = (q.astype(jnp.float32) * scale).astype(k_all.dtype)
    qf = qf.reshape(b, c, kv, g, hd)
    s = jnp.einsum("bckgd,bskd->bkgcs", qf, k_all,
                   preferred_element_type=jnp.float32)         # (b,kv,g,C,L+C)
    mask = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        mask &= (q_pos[:, :, None] - kpos[:, None, :]) < window
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgcs,bskd->bckgd", p.astype(v_all.dtype), v_all,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, c, h, hd).astype(q.dtype)


def decode_attention(q: jax.Array, cache: KVCache, pos: jax.Array) -> jax.Array:
    """One-token attention over the cache. q (B,1,H,hd) -> (B,1,H,hd).

    ``pos``: current absolute position (number of tokens already inserted,
    including this one).  With a ring buffer every slot written so far is a
    valid window member (softmax is permutation-invariant), so validity is
    just slot_index < pos for the full-cache case and "written" for rings.
    """
    b, _, h, hd = q.shape
    cap, kv = cache.k.shape[1], cache.k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    # keep the cache in its storage dtype — casting it to f32 would round-trip
    # the full cache through HBM every layer (§Perf iteration); accumulate the
    # contractions in f32 instead.
    qf = (q.astype(jnp.float32) * scale).astype(cache.k.dtype)
    qf = qf.reshape(b, kv, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, cache.k,
                   preferred_element_type=jnp.float32)       # (b,kv,g,cap)
    valid = jnp.arange(cap, dtype=jnp.int32)[None, :] < jnp.minimum(pos, cap)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(cache.v.dtype), cache.v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)
