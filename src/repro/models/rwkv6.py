"""RWKV-6 "Finch" (arXiv:2404.05892): token-shift with data-dependent lerp,
WKV6 linear recurrence with per-channel data-dependent decay, channel-mix FFN.

Sequence processing uses the chunked linear-attention formulation (GLA/FLA
style): within-chunk pairwise decays via two matmuls, across-chunk state carry
via a scan — train/prefill is MXU work, not a length-S scan.  Decode is the
O(1)-state recurrent step.

Numerics: per-step log-decay is clamped to >= LOG_W_MIN so the within-chunk
factorized exponentials exp(+/- cumsum(logw)) stay inside f32 range for
CHUNK steps (contributions decayed below e^{LOG_W_MIN} per step are zero at
f32 anyway — the same clamp fused GPU kernels apply to keep fp32 state sane).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import LinearCtx, linear

LORA_R = 32      # token-shift ddlerp LoRA rank
DECAY_R = 64     # decay LoRA rank
CHUNK = 16
LOG_W_MIN = -4.5  # with CHUNK=16: exp(-(C-1)*LOG_W_MIN) ~ e^67.5 < f32 max


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RWKVState:
    """Per-layer recurrent state for decode."""
    s: jax.Array          # (B, H, dk, dv) wkv state
    x_prev_tm: jax.Array  # (B, d) last token input to time-mix
    x_prev_cm: jax.Array  # (B, d) last token input to channel-mix

    @staticmethod
    def init(b: int, h: int, dk: int, d: int, dtype=jnp.float32):
        return RWKVState(s=jnp.zeros((b, h, dk, dk), jnp.float32),
                         x_prev_tm=jnp.zeros((b, d), dtype),
                         x_prev_cm=jnp.zeros((b, d), dtype))


def _ddlerp(p: dict, x: jax.Array, xx: jax.Array):
    """Data-dependent lerp mixes for (r, k, v, w, g) — RWKV6 token shift."""
    d = x.shape[-1]
    base = x + xx * p["mu_x"]
    low = jnp.tanh(jnp.einsum("...d,dr->...r", base,
                              p["tm_w1"].reshape(d, 5 * LORA_R)))
    low = low.reshape(*x.shape[:-1], 5, LORA_R)
    dyn = jnp.einsum("...fr,frd->...fd", low, p["tm_w2"])   # (..., 5, d)
    mix = p["mu_rkvwg"] + dyn
    return [x + xx * mix[..., i, :] for i in range(5)]


def _decay(p: dict, xw: jax.Array) -> jax.Array:
    """Per-channel log-decay: logw = -exp(w0 + lora(xw)), clamped."""
    lora = jnp.einsum("...r,rd->...d",
                      jnp.tanh(jnp.einsum("...d,dr->...r", xw, p["dw_a"])),
                      p["dw_b"])
    logw = -jnp.exp((p["w0"] + lora).astype(jnp.float32))
    return jnp.clip(logw, LOG_W_MIN, -1e-6)


def _project_rkvg(p: dict, xs, ctx, name):
    xr, xk, xv, xw, xg = xs
    r = linear(p["wr"], xr, ctx, f"{name}.wr")
    k = linear(p["wk"], xk, ctx, f"{name}.wk")
    v = linear(p["wv"], xv, ctx, f"{name}.wv")
    g = jax.nn.silu(linear(p["wg"], xg, ctx, f"{name}.wg"))
    logw = _decay(p, xw)
    return r, k, v, g, logw


def _out_proj(p: dict, out: jax.Array, g: jax.Array, ctx, name) -> jax.Array:
    """Per-head group norm -> gate -> output projection."""
    b, s, d = out.shape
    nh = p["u"].shape[0]
    oh = out.astype(jnp.float32).reshape(b, s, nh, d // nh)
    mu = jnp.mean(oh, axis=-1, keepdims=True)
    var = jnp.var(oh, axis=-1, keepdims=True)
    oh = (oh - mu) * jax.lax.rsqrt(var + 64e-5)
    out = oh.reshape(b, s, d) * p["ln_x"].astype(jnp.float32)
    out = (out * g.astype(jnp.float32)).astype(g.dtype)
    return linear(p["wo"], out, ctx, f"{name}.wo")


def time_mix(p: dict, x: jax.Array, *, n_heads: int, head_dim: int,
             chunk: int = CHUNK, ctx: LinearCtx | None = None,
             name: str = "tm", return_state: bool = False,
             state: RWKVState | None = None):
    """Parallel (chunked) WKV6 over x (B, S, d) -> (B, S, d).

    With ``return_state`` also returns the final (B, H, dk, dv) wkv state
    (prefill -> decode handoff).  ``state`` resumes from a mid-sequence
    handoff (chunked prefill): the wkv state and token-shift register are
    seeded from it instead of zeros.
    """
    b, s, d = x.shape
    h, dk = n_heads, head_dim
    if state is None:
        x_shift = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        x_shift = jnp.concatenate(
            [state.x_prev_tm.astype(x.dtype)[:, None], x[:, :-1]], axis=1)
    xs = _ddlerp(p, x, x_shift - x)
    r, k, v, g, logw = _project_rkvg(p, xs, ctx, name)
    u = p["u"].astype(jnp.float32)                           # (h, dk)

    nc = -(-s // chunk)
    sp = nc * chunk
    pad = ((0, 0), (0, sp - s), (0, 0))

    def heads(a):
        return jnp.moveaxis(jnp.pad(a, pad).reshape(b, nc, chunk, h, dk),
                            1, 0).astype(jnp.float32)        # (nc,b,C,h,dk)

    rs, ks, vs = heads(r), heads(k), heads(v)
    lw = heads(logw)
    # padding rows get logw = 0 => w = 1: state preserved, outputs discarded
    la = jnp.cumsum(lw, axis=2)                              # inclusive log-cumprod

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)

    def chunk_step(state, inputs):                           # state (b,h,dk,dk)
        rc, kc, vc, lac, lwc = inputs                        # (b,C,h,dk)
        la_prev = lac - lwc                                  # exclusive cumsum
        q_dec = rc * jnp.exp(la_prev)                        # <= |r|
        k_inv = kc * jnp.exp(-lac)                           # bounded via clamp
        scores = jnp.einsum("bchk,bshk->bhcs", q_dec, k_inv) * tri
        out = jnp.einsum("bhcs,bshv->bchv", scores, vc)      # intra, s < t
        diag = jnp.einsum("bchk,bchk->bch", rc * u[None, None], kc)
        out = out + diag[..., None] * vc                     # u-bonus (s = t)
        out = out + jnp.einsum("bchk,bhkv->bchv", q_dec, state)  # inter
        la_end = lac[:, -1]                                  # (b,h,dk)
        k_carry = kc * jnp.exp(la_end[:, None] - lac)        # <= |k|
        state = (state * jnp.exp(la_end)[..., None]
                 + jnp.einsum("bshk,bshv->bhkv", k_carry, vc))
        return state, out

    s0 = (jnp.zeros((b, h, dk, dk), jnp.float32) if state is None
          else state.s.astype(jnp.float32))
    s_final, outs = jax.lax.scan(chunk_step, s0, (rs, ks, vs, la, lw))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sp, h * dk)[:, :s]
    y = _out_proj(p, out, g, ctx, name)
    if return_state:
        return y, s_final
    return y


def time_mix_decode(p: dict, x: jax.Array, state: RWKVState, *, n_heads: int,
                    head_dim: int, ctx: LinearCtx | None = None,
                    name: str = "tm"):
    """One token: x (B, d) -> (out (B, d), new wkv state + shift reg)."""
    b, d = x.shape
    h, dk = n_heads, head_dim
    xs = _ddlerp(p, x, state.x_prev_tm - x)
    r, k, v, g, logw = _project_rkvg(p, xs, ctx, name)
    w = jnp.exp(logw.astype(jnp.float32)).reshape(b, h, dk)
    rh = r.astype(jnp.float32).reshape(b, h, dk)
    kh = k.astype(jnp.float32).reshape(b, h, dk)
    vh = v.astype(jnp.float32).reshape(b, h, dk)
    u = p["u"].astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    out = jnp.einsum("bhk,bhkv->bhv", rh, state.s + u[None, :, :, None] * kv)
    s_new = state.s * w[..., None] + kv
    out = _out_proj(p, out.reshape(b, 1, h * dk), g.reshape(b, 1, d), ctx, name)
    return out[:, 0], dataclasses.replace(state, s=s_new, x_prev_tm=x)


def channel_mix(p: dict, x: jax.Array, x_prev: jax.Array | None = None,
                ctx: LinearCtx | None = None, name: str = "cm") -> jax.Array:
    """RWKV6 channel-mix.  Sequence mode for x (B,S,d) — with ``x_prev``
    (B,d) seeding the token-shift register for mid-sequence continuation —
    or one step for x (B,d) with the explicit shift register."""
    if x.ndim == 3:
        if x_prev is None:
            xs = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        else:
            xs = jnp.concatenate([x_prev.astype(x.dtype)[:, None], x[:, :-1]],
                                 axis=1)
    else:
        xs = x_prev
    xx = xs - x
    xk = x + xx * p["mu_k"]
    xr = x + xx * p["mu_r"]
    k = jnp.square(jax.nn.relu(linear(p["ck"], xk, ctx, f"{name}.ck")))
    kv = linear(p["cv"], k, ctx, f"{name}.cv")
    return jax.nn.sigmoid(linear(p["cr"], xr, ctx, f"{name}.cr")) * kv
