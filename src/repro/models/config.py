"""ModelConfig: a single declarative description that covers all ten assigned
architectures (dense GQA / MoE / MLA / RWKV6 / RG-LRU hybrid / enc-dec)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts, DeepSeek style
    capacity_factor: float = 1.25
    aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128
    n_heads: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | mla_moe | rwkv6 | rglru | whisper | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    norm: str = "rmsnorm"
    act: str = "silu"
    pos: str = "rope"            # rope | mrope | sinusoidal | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()
    qk_norm: bool = False
    window: Optional[int] = None  # sliding/local attention window
    mixer_pattern: Tuple[str, ...] = ()  # per layer: attn | mla | rwkv | rglru
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru_width: Optional[int] = None
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_audio_ctx: int = 1500
    subquadratic: bool = False   # long_500k applicability (DESIGN.md §4)
    frontend: str = "none"       # none | audio_stub | vision_stub
    # --- perf knobs (EXPERIMENTS.md §Perf) ---
    remat_attention: bool = False  # flash-style bwd: recompute per-chunk
    #   probabilities instead of stacking S^2 residuals (checkpointed kv_step)
    expand_kv: bool = False        # expand GQA KV heads to full heads so the
    #   attention einsums shard on the flat head axis (§Perf B2)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.mixer_pattern:
            assert len(self.mixer_pattern) == self.n_layers
            return self.mixer_pattern
        return ("attn",) * self.n_layers

    @property
    def scan_period(self) -> int:
        """Smallest p such that the mixer pattern is (prefix of) a p-cycle."""
        pat = self.pattern
        for p in range(1, len(pat) + 1):
            if all(pat[i] == pat[i % p] for i in range(len(pat))):
                return p
        return len(pat)

    def ffn_kind(self) -> str:
        if self.family in ("rwkv6",):
            return "cm"
        if self.moe is not None:
            return "moe"
        return "glu" if self.act in ("silu", "geglu") else "gelu"

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
