"""Pure-JAX composable model zoo (assigned architectures, DESIGN.md §4)."""
