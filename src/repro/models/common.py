"""Shared building blocks: the linear chokepoint, norms, RoPE/M-RoPE, losses.

Every weight multiplication in the zoo goes through ``linear`` so that
 (a) RaanA calibration can tap per-layer stats / inject output perturbations
     (the d f / d H^{(k)} probe of paper §4) via a ``LinearCtx``, and
 (b) quantized models are just param trees whose 2-D weights were swapped for
     ``QuantizedLinear`` nodes — dispatch happens here, model code unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qlinear import QuantizedLinear

# ---------------------------------------------------------------- linear tap


class LinearCtx:
    """Mutable-during-trace collector for calibration (unrolled mode only).

    ``collect_hessian`` additionally accumulates the layer-wise Hessian
    X^T X (d, d) per linear — needed only by the GPTQ baseline (the paper's
    point is precisely that RaanA does NOT need it)."""

    def __init__(self, perturb: dict | None = None, collect: bool = False,
                 collect_hessian: bool = False):
        self.perturb = perturb
        self.collect = collect
        self.collect_hessian = collect_hessian
        self.taps: dict[str, dict] = {}
        self.hessians: dict[str, jax.Array] = {}


def linear(w, x: jax.Array, ctx: Optional[LinearCtx] = None,
           name: str | None = None) -> jax.Array:
    """y = x @ w for w either a raw (d, c) array or a QuantizedLinear."""
    if isinstance(w, QuantizedLinear):
        return w.apply(x)
    y = jnp.einsum("...d,dc->...c", x, w.astype(x.dtype))
    if ctx is not None and name is not None:
        if ctx.collect_hessian:
            x2 = x.astype(jnp.float32).reshape(-1, x.shape[-1])
            h = x2.T @ x2
            prev = ctx.hessians.get(name)
            ctx.hessians[name] = h if prev is None else prev + h
        if ctx.collect:
            xf = x.astype(jnp.float32)
            ctx.taps[name] = dict(
                x_fro_sq=jnp.sum(xf * xf),
                x_col_sq=jnp.sum(xf * xf, axis=tuple(range(x.ndim - 1))),
                w_fro=jnp.linalg.norm(w.astype(jnp.float32)),
                n_rows=jnp.asarray(x.size // x.shape[-1], jnp.float32),
                d=w.shape[0], c=w.shape[1], h_shape=y.shape)
        if ctx.perturb is not None and name in ctx.perturb:
            y = y + ctx.perturb[name].astype(y.dtype)
    return y


# ------------------------------------------------------------------- norms


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(kind: str, x: jax.Array, p: dict) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def norm_params(kind: str, d: int) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


# -------------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """x (B, S, H, hd); positions (B, S) -> rotated x (half-split convention)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                                   # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv          # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, sections: tuple[int, ...],
                theta: float = 1_000_000.0) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): positions (3, B, S); rotary angle channels
    are sectioned across (temporal, height, width) position streams."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                                   # (hd/2,)
    ang_all = positions[..., None].astype(jnp.float32) * inv      # (3, B, S, hd/2)
    import numpy as np
    sec_id = jnp.asarray(np.repeat(np.arange(len(sections)), sections))  # (hd/2,)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_all, 0, -1), sec_id[None, None, :, None], axis=-1
    )[..., 0]                                                     # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (n, d)."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / max(d // 2 - 1, 1)))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# -------------------------------------------------------------------- loss


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean NLL; logits (..., V) computed in f32 for stability."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# -------------------------------------------------------------------- init


def dense_init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None) -> jax.Array:
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def split_keys(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))
