"""Serving paths: cache init, prefill (sequence -> logits + caches), and the
single-token decode step, for every mixer family.

Cache layout mirrors ``params["layers"]``: one stacked cache tree per scan
position, so decode scans layers and caches together.  Sliding-window archs
get ring-buffered KV caches (capacity = window); attention-free mixers carry
O(1) recurrent state — which is precisely why they are the archs that can
serve the long_500k cell (DESIGN.md §4).

Quantized decode is memory-bound: every linear here dispatches (via
``common.linear`` / ``moe._expert_matmul``) to the fused RHT+qmatmul kernel
(DESIGN.md §6), so single-token weights move HBM->VMEM packed at b/16 of the
bf16 cost and the rotation happens in VMEM — no rotated-activation round trip
between kernels.

The ``*_paged`` variants at the bottom are the continuous-batching serving
path (DESIGN.md §7): attention K/V lives in a shared block arena addressed
via per-request block tables, recurrent/MLA state in per-slot arrays, and
the decode step takes fixed-shape (tokens, pos, active, block_tables,
ring_cap) arrays so it compiles once no matter how the batch churns.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.runtime import tp as tpmod

from . import attention as attnmod
from . import mla as mlamod
from . import rglru as rglrumod
from . import rwkv6 as rwkvmod
from .attention import KVCache
from .common import (apply_mrope, apply_norm, apply_rope, linear,
                     rms_norm, sinusoidal_positions)
from .config import ModelConfig
from .transformer import (_ffn_apply, _qk_normalize, embed_tokens, encode,
                          get_layer, layer_seq, layers_scannable)

# ----------------------------------------------------------- cache structs


def attn_capacity(cfg: ModelConfig, context: int) -> int:
    return min(context, cfg.window) if cfg.window else context


def init_layer_cache(cfg: ModelConfig, mixer: str, b: int, context: int,
                     dtype=jnp.float32, encoder_out=None, lp=None) -> dict:
    cache: dict[str, Any] = {}
    if mixer == "attn":
        cache["kv"] = KVCache.init(b, attn_capacity(cfg, context), cfg.n_kv,
                                   cfg.hd, dtype)
    elif mixer == "mla":
        cache["mla"] = mlamod.MLACache.init(b, context, cfg.mla.kv_lora,
                                            cfg.mla.qk_rope, dtype)
    elif mixer == "rwkv":
        cache["rwkv"] = rwkvmod.RWKVState.init(b, cfg.n_heads, cfg.hd,
                                               cfg.d_model, dtype)
    elif mixer == "rglru":
        cache["rglru"] = rglrumod.RGLRUState.init(
            b, cfg.rglru_width or cfg.d_model, dtype)
    if cfg.enc_dec:
        assert encoder_out is not None and lp is not None
        t = encoder_out.shape[1]
        k = linear(lp["xattn"]["wk"], encoder_out).reshape(
            b, t, cfg.n_kv, cfg.hd)
        v = linear(lp["xattn"]["wv"], encoder_out).reshape(
            b, t, cfg.n_kv, cfg.hd)
        cache["xk"], cache["xv"] = k, v
    return cache


def init_caches(cfg: ModelConfig, params: dict, b: int, context: int,
                dtype=jnp.float32, encoder_out=None) -> list:
    """One stacked cache tree per scan position (parallel to params layers)."""
    pat, p = cfg.pattern, cfg.scan_period
    caches = []
    for j in range(p):
        stack = params["layers"][j]
        n_j = (len(stack) if isinstance(stack, list)
               else jax.tree.leaves(stack)[0].shape[0])

        def one(i):
            lp = (stack[i] if isinstance(stack, list)
                  else jax.tree.map(lambda a: a[i], stack))
            return init_layer_cache(cfg, pat[j], b, context, dtype,
                                    encoder_out, lp)
        caches.append(jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                                   *[one(i) for i in range(n_j)]))
    return caches


# ----------------------------------------------------------------- prefill


def _attn_qkv(cfg: ModelConfig, p: dict, hn: jax.Array, positions):
    """Shared attention-mixer projection: q/k/v + qk-norm + rope/mrope.

    hn (B, S, d); positions (B, S), or (3, B, S) for mrope.  Used by every
    serving path (prefill, decode, and their paged variants) so positional
    handling can't drift between them.  Head counts are derived from the
    projection outputs, not ``cfg``: under tensor-parallel serving
    (runtime/tp.py) ``wq``/``wk``/``wv`` arrive column-sharded inside
    ``shard_map`` and each shard sees its local head slice; rope/qk-norm
    are per-head so they apply to the slice unchanged.
    """
    b, s, _ = hn.shape
    hd = cfg.hd
    q = linear(p["wq"], hn).reshape(b, s, -1, hd)
    k = linear(p["wk"], hn).reshape(b, s, -1, hd)
    v = linear(p["wv"], hn).reshape(b, s, -1, hd)
    q, k = _qk_normalize(p, q, k)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos == "mrope":
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    return q, k, v


def _ring_fill(cache: KVCache, k: jax.Array, v: jax.Array) -> KVCache:
    """Fill a ring cache from a full prefix (B, S, kv, hd): keep last cap."""
    cap = cache.k.shape[1]
    s = k.shape[1]
    if s <= cap:
        return KVCache(k=cache.k.at[:, :s].set(k.astype(cache.k.dtype)),
                       v=cache.v.at[:, :s].set(v.astype(cache.v.dtype)))
    tail_t = jnp.arange(s - cap, s)
    slots = tail_t % cap
    return KVCache(k=cache.k.at[:, slots].set(k[:, tail_t].astype(cache.k.dtype)),
                   v=cache.v.at[:, slots].set(v[:, tail_t].astype(cache.v.dtype)))


def layer_prefill(cfg: ModelConfig, mixer: str, lp: dict, h: jax.Array,
                  positions, cache: dict, encoder_out=None):
    """Sequence forward through one layer, also filling its cache.
    Returns (h, aux, new_cache)."""
    b, s, d = h.shape
    hn = apply_norm(cfg.norm, h, lp["ln1"])
    new_cache = dict(cache)
    if mixer == "attn":
        p = lp["attn"]
        q, k, v = _attn_qkv(cfg, p, hn, positions)
        out = attnmod.flash_attention(q, k, v, causal=True, window=cfg.window,
                                      expand_kv=cfg.expand_kv)
        mix = linear(p["wo"], out.reshape(b, s, cfg.n_heads * cfg.hd))
        from repro.runtime.actsharding import shard_named
        new_cache["kv"] = _ring_fill(cache["kv"], shard_named(k, "kv"),
                                     shard_named(v, "kv"))
    elif mixer == "mla":
        m, p = cfg.mla, lp["mla"]
        mix = mlamod.mla_full(p, hn, m, positions)
        c_kv, k_rope = mlamod._project_kv_latent(p, hn, m, positions, None, "")
        new_cache["mla"] = mlamod.MLACache(
            c_kv=cache["mla"].c_kv.at[:, :s].set(
                c_kv.astype(cache["mla"].c_kv.dtype)),
            k_rope=cache["mla"].k_rope.at[:, :s].set(
                k_rope.astype(cache["mla"].k_rope.dtype)))
    elif mixer == "rwkv":
        mix, st = rwkvmod.time_mix(lp["tm"], hn, n_heads=cfg.n_heads,
                                   head_dim=cfg.hd, return_state=True)
        new_cache["rwkv"] = rwkvmod.RWKVState(
            s=st, x_prev_tm=hn[:, -1].astype(cache["rwkv"].x_prev_tm.dtype),
            x_prev_cm=cache["rwkv"].x_prev_cm)
    elif mixer == "rglru":
        mix, st = rglrumod.rglru_block(lp["rglru"], hn, return_state=True)
        new_cache["rglru"] = st
    else:
        raise ValueError(mixer)
    h = h + mix.astype(h.dtype)
    if cfg.enc_dec:
        hx = apply_norm(cfg.norm, h, lp["ln_x"])
        q = linear(lp["xattn"]["wq"], hx).reshape(b, s, cfg.n_heads, cfg.hd)
        out = attnmod.flash_attention(
            q, cache["xk"], cache["xv"], causal=False)
        h = h + linear(lp["xattn"]["wo"], out.reshape(b, s, -1))
    h2 = apply_norm(cfg.norm, h, lp["ln2"])
    if mixer == "rwkv":
        y = rwkvmod.channel_mix(lp["cm"], h2)
        new_cache["rwkv"] = rwkvmod.RWKVState(
            s=new_cache["rwkv"].s, x_prev_tm=new_cache["rwkv"].x_prev_tm,
            x_prev_cm=h2[:, -1].astype(cache["rwkv"].x_prev_cm.dtype))
        aux = 0.0
    else:
        y, aux = _ffn_apply(cfg, lp, h2, None, "pf")
    from repro.runtime.actsharding import shard_hidden
    return shard_hidden(h + y.astype(h.dtype)), aux, new_cache


def _apply_layers(cfg: ModelConfig, params: dict, caches: list, h: jax.Array,
                  layer_fn, scan: bool):
    """Shared layer-stack driver for every serving path.

    ``layer_fn(mixer, lp, h, cache) -> (h, new_cache)`` is applied to the
    layers in execution order, scanning full periods of the mixer pattern
    when the param/cache trees are stackable and unrolling otherwise
    (quantized models with heterogeneous per-layer bit widths).  Returns
    (h, new_caches) with new_caches stacked parallel to ``params['layers']``.
    """
    scan = scan and layers_scannable(params)
    pat, p_period = cfg.pattern, cfg.scan_period
    n_full = cfg.n_layers // p_period
    rem = cfg.n_layers % p_period
    new_caches = [None] * p_period

    if scan and n_full > 0:
        full_stacks = [jax.tree.map(lambda a: a[:n_full], st)
                       for st in params["layers"]]
        full_caches = [jax.tree.map(lambda a: a[:n_full], cs) for cs in caches]

        def body(hh, xs):
            lps, cs = xs
            outs = []
            for j in range(p_period):
                hh, nc = layer_fn(pat[j], lps[j], hh, cs[j])
                outs.append(nc)
            return hh, tuple(outs)

        h, scanned = jax.lax.scan(body, h, (tuple(full_stacks),
                                            tuple(full_caches)))
        new_caches = list(scanned)
        for j in range(rem):
            lp = jax.tree.map(lambda a: a[n_full], params["layers"][j])
            cs = jax.tree.map(lambda a: a[n_full], caches[j])
            h, nc = layer_fn(pat[j], lp, h, cs)
            new_caches[j] = jax.tree.map(
                lambda full, one: jnp.concatenate([full, one[None]], 0),
                new_caches[j], nc)
    else:
        percall = [[] for _ in range(p_period)]
        for i in range(cfg.n_layers):
            jpos, idx = i % p_period, i // p_period
            lp = get_layer(params, jpos, idx)
            cs = jax.tree.map(lambda a: a[idx], caches[jpos])
            h, nc = layer_fn(pat[i], lp, h, cs)
            percall[jpos].append(nc)
        new_caches = [jax.tree.map(lambda *xs: jnp.stack(xs, 0), *cl)
                      for cl in percall]
    return h, new_caches


def prefill(cfg: ModelConfig, params: dict, tokens=None, *, embeds=None,
            positions=None, context: int | None = None, enc_embeds=None,
            cache_dtype=jnp.float32, scan: bool = True):
    """Run the prefix, return (logits (B, S, V), caches, pos = S)."""
    h = embeds if embeds is not None else embed_tokens(cfg, params, tokens)
    b, s, d = h.shape
    context = context or s
    encoder_out = None
    if cfg.enc_dec:
        encoder_out = encode(cfg, params, enc_embeds, scan=scan)
    if cfg.pos == "sinusoidal":
        h = h + sinusoidal_positions(s, d).astype(h.dtype)[None]
    if positions is None:
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        positions = (jnp.broadcast_to(pos[None], (3, b, s))
                     if cfg.pos == "mrope" else pos)
    caches = init_caches(cfg, params, b, context, cache_dtype, encoder_out)

    def fn(mixer, lp, hh, cs):
        hh, _, nc = layer_prefill(cfg, mixer, lp, hh, positions, cs,
                                  encoder_out)
        return hh, nc

    h, new_caches = _apply_layers(cfg, params, caches, h, fn, scan)
    h = apply_norm(cfg.norm, h, params["final_norm"])
    logits = linear(params["lm_head"], h)
    return logits, new_caches, jnp.int32(s)


# ------------------------------------------------------------ decode step


def layer_decode(cfg: ModelConfig, mixer: str, lp: dict, h: jax.Array,
                 cache: dict, pos: jax.Array):
    """One layer, one token: h (B, 1, d) -> (h, new_cache)."""
    b = h.shape[0]
    hn = apply_norm(cfg.norm, h, lp["ln1"])
    new_cache = dict(cache)
    if mixer == "attn":
        p = lp["attn"]
        posb = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
        if cfg.pos == "mrope":
            posb = jnp.broadcast_to(pos, (3, b, 1)).astype(jnp.int32)
        q, k, v = _attn_qkv(cfg, p, hn, posb)
        kvc = attnmod.cache_insert(cache["kv"], k, v, pos)
        out = attnmod.decode_attention(q, kvc, pos + 1)
        mix = linear(p["wo"], out.reshape(b, 1, cfg.n_heads * cfg.hd))
        new_cache["kv"] = kvc
    elif mixer == "mla":
        mix, mc = mlamod.mla_decode(lp["mla"], hn, cfg.mla, cache["mla"], pos)
        new_cache["mla"] = mc
    elif mixer == "rwkv":
        mix, st = rwkvmod.time_mix_decode(lp["tm"], hn[:, 0],
                                          cache["rwkv"], n_heads=cfg.n_heads,
                                          head_dim=cfg.hd)
        mix = mix[:, None, :]
        new_cache["rwkv"] = st
    elif mixer == "rglru":
        mix, st = rglrumod.rglru_decode(lp["rglru"], hn[:, 0], cache["rglru"])
        mix = mix[:, None, :]
        new_cache["rglru"] = st
    else:
        raise ValueError(mixer)
    h = h + mix.astype(h.dtype)
    if cfg.enc_dec:
        hx = apply_norm(cfg.norm, h, lp["ln_x"])
        q = linear(lp["xattn"]["wq"], hx).reshape(b, 1, cfg.n_heads, cfg.hd)
        t = cache["xk"].shape[1]
        out = attnmod.decode_attention(
            q, KVCache(k=cache["xk"], v=cache["xv"]), jnp.int32(t))
        h = h + linear(lp["xattn"]["wo"], out.reshape(b, 1, -1))
    h2 = apply_norm(cfg.norm, h, lp["ln2"])
    if mixer == "rwkv":
        y = rwkvmod.channel_mix(lp["cm"], h2[:, 0],
                                new_cache["rwkv"].x_prev_cm)[:, None, :]
        st = new_cache["rwkv"]
        new_cache["rwkv"] = rwkvmod.RWKVState(
            s=st.s, x_prev_tm=st.x_prev_tm,
            x_prev_cm=h2[:, 0].astype(st.x_prev_cm.dtype))
    else:
        y, _ = _ffn_apply(cfg, lp, h2, None, "dec")
    return h + y.astype(h.dtype), new_cache


def decode_step(cfg: ModelConfig, params: dict, caches: list,
                tokens: jax.Array, pos: jax.Array, scan: bool = True):
    """One token for the whole model: tokens (B, 1) -> (logits (B, V),
    new caches).  ``pos`` = number of tokens already in the cache."""
    h = embed_tokens(cfg, params, tokens)
    if cfg.pos == "sinusoidal":
        d = h.shape[-1]
        table = sinusoidal_positions(caches_context(caches, cfg), d)
        h = h + jax.lax.dynamic_slice_in_dim(table, pos, 1, 0)[None].astype(h.dtype)

    def fn(mixer, lp, hh, cs):
        return layer_decode(cfg, mixer, lp, hh, cs, pos)

    h, new_caches = _apply_layers(cfg, params, caches, h, fn, scan)
    h = apply_norm(cfg.norm, h, params["final_norm"])
    logits = linear(params["lm_head"], h)
    return logits[:, 0], new_caches


# ------------------------------------------------- paged serving variants
#
# The continuous-batching engine (repro/serve) keeps one fixed set of ``S``
# slots; attention K/V lives in a shared block arena addressed through
# per-slot block tables, and MLA/RWKV/RG-LRU recurrent state lives in
# per-slot arrays.  Every argument that changes as the batch composition
# churns (tokens, positions, active mask, block tables, ring capacities) is
# an *array* of static shape, so the jitted step traces exactly once.


def _mask_state(old, new, active: jax.Array):
    """Keep ``old`` state rows where ``active`` is False (slot-array pytrees)."""
    def sel(o, n):
        m = active.reshape(active.shape[0], *([1] * (o.ndim - 1)))
        return jnp.where(m, n.astype(o.dtype), o)
    return jax.tree.map(sel, old, new)


def layer_decode_paged(cfg: ModelConfig, mixer: str, lp: dict, h: jax.Array,
                       cache: dict, pos: jax.Array, active: jax.Array,
                       block_tables: jax.Array, ring_cap: jax.Array):
    """One layer, one token per slot, against the paged cache pool.

    h (S, 1, d); pos (S,) per-slot token counts (the fed token's absolute
    position); active (S,) request-occupancy mask; block_tables (S, MB);
    ring_cap (S,) per-slot ring capacities in tokens.
    """
    b = h.shape[0]
    hn = apply_norm(cfg.norm, h, lp["ln1"])
    new_cache = dict(cache)
    if mixer == "attn":
        p = lp["attn"]
        posb = pos[:, None].astype(jnp.int32)
        if cfg.pos == "mrope":
            posb = jnp.broadcast_to(pos[None, :, None], (3, b, 1)).astype(jnp.int32)
        q, k, v = _attn_qkv(cfg, p, hn, posb)
        block_size = cache["k"].shape[1]
        pb, off = attnmod.paged_write_indices(pos, ring_cap, block_tables,
                                              block_size, active)
        k_arena = cache["k"].at[pb, off].set(k[:, 0].astype(cache["k"].dtype))
        v_arena = cache["v"].at[pb, off].set(v[:, 0].astype(cache["v"].dtype))
        out = attnmod.paged_decode_attention(q, k_arena, v_arena, block_tables,
                                             pos + 1, ring_cap,
                                             window=cfg.window)
        out = tpmod.gather_heads(out, cfg.n_heads)
        mix = linear(p["wo"], out.reshape(b, 1, -1))
        new_cache["k"], new_cache["v"] = k_arena, v_arena
    elif mixer == "mla":
        mix, mc = mlamod.mla_decode_paged(lp["mla"], hn, cfg.mla,
                                          cache["mla"], pos, active)
        new_cache["mla"] = mc
    elif mixer == "rwkv":
        mix, st = rwkvmod.time_mix_decode(lp["tm"], hn[:, 0], cache["rwkv"],
                                          n_heads=cfg.n_heads,
                                          head_dim=cfg.hd)
        mix = mix[:, None, :]
        new_cache["rwkv"] = _mask_state(cache["rwkv"], st, active)
    elif mixer == "rglru":
        mix, st = rglrumod.rglru_decode(lp["rglru"], hn[:, 0], cache["rglru"])
        mix = mix[:, None, :]
        new_cache["rglru"] = _mask_state(cache["rglru"], st, active)
    else:
        raise ValueError(mixer)
    h = h + mix.astype(h.dtype)
    h2 = apply_norm(cfg.norm, h, lp["ln2"])
    if mixer == "rwkv":
        y = rwkvmod.channel_mix(lp["cm"], h2[:, 0],
                                new_cache["rwkv"].x_prev_cm)[:, None, :]
        st = new_cache["rwkv"]
        new_cache["rwkv"] = _mask_state(
            st, rwkvmod.RWKVState(s=st.s, x_prev_tm=st.x_prev_tm,
                                  x_prev_cm=h2[:, 0]), active)
    else:
        y, _ = _ffn_apply(cfg, lp, h2, None, "dec")
    return h + y.astype(h.dtype), new_cache


def decode_step_paged(cfg: ModelConfig, params: dict, caches: list,
                      tokens: jax.Array, pos: jax.Array, active: jax.Array,
                      block_tables: jax.Array, ring_cap: jax.Array,
                      scan: bool = True):
    """One decode step for the whole slot set: tokens (S, 1) -> (logits
    (S, V), new caches).  Inactive slots run inert (embeddings zeroed, cache
    writes redirected/no-op'd) so the compiled step is reused unchanged while
    requests come and go.
    """
    if cfg.enc_dec:
        raise NotImplementedError(
            "paged serving does not support encoder-decoder archs")
    h = embed_tokens(cfg, params, tokens)
    if cfg.pos == "sinusoidal":
        d = h.shape[-1]
        table = sinusoidal_positions(caches_context(caches, cfg), d)
        h = h + table[jnp.minimum(pos, table.shape[0] - 1)][:, None].astype(h.dtype)
    h = jnp.where(active[:, None, None], h, 0)

    def fn(mixer, lp, hh, cs):
        return layer_decode_paged(cfg, mixer, lp, hh, cs, pos, active,
                                  block_tables, ring_cap)

    h, new_caches = _apply_layers(cfg, params, caches, h, fn, scan)
    h = apply_norm(cfg.norm, h, params["final_norm"])
    logits = tpmod.gather_cols(linear(params["lm_head"], h), cfg.vocab)
    return logits[:, 0], new_caches


def layer_verify_paged(cfg: ModelConfig, mixer: str, lp: dict, h: jax.Array,
                       cache: dict, pos0: jax.Array, active: jax.Array,
                       block_tables: jax.Array, ring_cap: jax.Array,
                       write_mask: jax.Array):
    """One layer, W tokens per slot, against the paged pool (speculative
    verify / draft catch-up).

    h (S, W, d); pos0 (S,) each slot's first absolute position; active (S,);
    block_tables (S, MB); ring_cap (S,); write_mask (S, W) selects which of
    the W positions commit KV to the arena (masked writes land on the null
    block).  Attention gathers pre-``pos0`` history from the arena exactly
    like chunked prefill and is causal within the W-token span, so the
    logits at position ``pos0 + i`` condition on the first i fed tokens —
    the property the acceptance rule needs.  Attention-only: recurrent/MLA
    state is sequential (re-feeding positions would corrupt it), which is
    why those archs bypass speculation (DESIGN.md §9).
    """
    if mixer != "attn":
        raise NotImplementedError(
            f"speculative verify supports attention mixers only (got "
            f"{mixer!r}); recurrent/MLA archs bypass speculation")
    from repro.kernels.paged_attention import ops as pops  # late: no cycle
    b, w, d = h.shape
    hn = apply_norm(cfg.norm, h, lp["ln1"])
    new_cache = dict(cache)
    positions = pos0[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
    p = lp["attn"]
    posb = positions
    if cfg.pos == "mrope":
        posb = jnp.broadcast_to(positions[None], (3, b, w)).astype(jnp.int32)
    q, k, v = _attn_qkv(cfg, p, hn, posb)
    block_size = cache["k"].shape[1]
    pb, off = attnmod.paged_multi_write_indices(positions, ring_cap,
                                                block_tables, block_size,
                                                write_mask)
    new_cache["k"] = cache["k"].at[pb, off].set(k.astype(cache["k"].dtype))
    new_cache["v"] = cache["v"].at[pb, off].set(v.astype(cache["v"].dtype))
    if pops.kernel_enabled():
        # kernel path: the span's K/V is committed above, so one flash-decode
        # sweep over the arena covers history + span (causality within the
        # span falls out of the stored-position mask).  Write-before-read is
        # safe: a masked position is either an inactive slot (output unread)
        # or a catch-up position whose identical K/V is already arena-
        # resident, and PoolConfig.lookahead reserves the ring capacity the
        # up-to-W-past-frontier writes land in (DESIGN.md §9/§10).
        out = pops.paged_attention(q, new_cache["k"], new_cache["v"],
                                   block_tables, pos0 + w, ring_cap,
                                   window=cfg.window)
    else:
        k_hist = attnmod.paged_gather_kv(cache["k"], block_tables)
        v_hist = attnmod.paged_gather_kv(cache["v"], block_tables)
        hist_pos = attnmod.paged_slot_positions(pos0, ring_cap,
                                                k_hist.shape[1])
        out = attnmod.paged_prefill_attention(q, k_hist, v_hist, hist_pos,
                                              k, v, positions,
                                              window=cfg.window)
    out = tpmod.gather_heads(out, cfg.n_heads)
    mix = linear(p["wo"], out.reshape(b, w, -1))
    h = h + mix.astype(h.dtype)
    h2 = apply_norm(cfg.norm, h, lp["ln2"])
    y, _ = _ffn_apply(cfg, lp, h2, None, "ver")
    return h + y.astype(h.dtype), new_cache


def decode_verify_paged(cfg: ModelConfig, params: dict, caches: list,
                        tokens: jax.Array, pos0: jax.Array, active: jax.Array,
                        block_tables: jax.Array, ring_cap: jax.Array,
                        write_mask: jax.Array, scan: bool = True):
    """Score W tokens per slot in one batched step: tokens (S, W) starting
    at per-slot positions ``pos0`` -> (logits (S, W, V), new caches).

    The speculative-decoding workhorse (DESIGN.md §9): the target model
    verifies a draft's k proposals (W = k+1: last accepted token + k drafts)
    in a single fixed-shape dispatch, and the draft model uses the same step
    at W = 2 to catch up after an all-accept round.  Like
    ``decode_step_paged``, every churning input is a fixed-shape array, so
    the step compiles exactly once per (model, W).  Inactive slots run inert
    (embeddings zeroed, writes redirected to the null block).
    """
    if cfg.enc_dec:
        raise NotImplementedError(
            "paged serving does not support encoder-decoder archs")
    h = embed_tokens(cfg, params, tokens)
    if cfg.pos == "sinusoidal":
        d = h.shape[-1]
        table = sinusoidal_positions(caches_context(caches, cfg), d)
        positions = pos0[:, None] + jnp.arange(tokens.shape[1],
                                               dtype=jnp.int32)[None, :]
        h = h + table[jnp.minimum(positions, table.shape[0] - 1)].astype(h.dtype)
    h = jnp.where(active[:, None, None], h, 0)
    wmask = write_mask & active[:, None]

    def fn(mixer, lp, hh, cs):
        return layer_verify_paged(cfg, mixer, lp, hh, cs, pos0, active,
                                  block_tables, ring_cap, wmask)

    h, new_caches = _apply_layers(cfg, params, caches, h, fn, scan)
    h = apply_norm(cfg.norm, h, params["final_norm"])
    logits = tpmod.gather_cols(linear(params["lm_head"], h), cfg.vocab)
    return logits, new_caches


def layer_prefill_chunk(cfg: ModelConfig, mixer: str, lp: dict, h: jax.Array,
                        cache: dict, pos0: jax.Array, slot: jax.Array,
                        bt_row: jax.Array, ring_cap: jax.Array):
    """One layer over one request's prompt chunk h (1, C, d), reading and
    writing the paged pool at the request's slot / block-table row.

    ``pos0`` is the chunk's first absolute position; recurrent state is read
    from the slot arrays (zeros when pos0 == 0, i.e. a freshly admitted
    request on a recycled slot) and written back after the chunk.
    """
    b, c, d = h.shape
    hn = apply_norm(cfg.norm, h, lp["ln1"])
    new_cache = dict(cache)
    chunk_pos = pos0 + jnp.arange(c, dtype=jnp.int32)

    def slot_state(tree):
        return jax.tree.map(
            lambda a: jnp.where(pos0 > 0, a[slot], jnp.zeros_like(a[slot]))[None],
            tree)

    def store_state(tree, new):
        return jax.tree.map(lambda a, n: a.at[slot].set(n[0].astype(a.dtype)),
                            tree, new)

    if mixer == "attn":
        p = lp["attn"]
        positions = chunk_pos[None]
        if cfg.pos == "mrope":
            positions = jnp.broadcast_to(chunk_pos[None, None], (3, 1, c))
        q, k, v = _attn_qkv(cfg, p, hn, positions)
        k_hist = attnmod.paged_gather_kv(cache["k"], bt_row[None])
        v_hist = attnmod.paged_gather_kv(cache["v"], bt_row[None])
        hist_pos = attnmod.paged_slot_positions(pos0[None], ring_cap[None],
                                                k_hist.shape[1])
        out = attnmod.paged_prefill_attention(
            q, k_hist, v_hist, hist_pos, k, v, chunk_pos[None],
            window=cfg.window)
        out = tpmod.gather_heads(out, cfg.n_heads)
        mix = linear(p["wo"], out.reshape(b, c, -1))
        block_size = cache["k"].shape[1]
        pb, off = attnmod.paged_write_indices(chunk_pos, ring_cap, bt_row,
                                              block_size)
        new_cache["k"] = cache["k"].at[pb, off].set(
            k[0].astype(cache["k"].dtype))
        new_cache["v"] = cache["v"].at[pb, off].set(
            v[0].astype(cache["v"].dtype))
    elif mixer == "mla":
        mix, mc = mlamod.mla_prefill_chunk(lp["mla"], hn, cfg.mla,
                                           cache["mla"], pos0, slot)
        new_cache["mla"] = mc
    elif mixer == "rwkv":
        rwkv_st0 = slot_state(cache["rwkv"])
        mix, s_new = rwkvmod.time_mix(lp["tm"], hn, n_heads=cfg.n_heads,
                                      head_dim=cfg.hd, return_state=True,
                                      state=rwkv_st0)
        rwkv_st = rwkvmod.RWKVState(
            s=s_new, x_prev_tm=hn[:, -1].astype(rwkv_st0.x_prev_tm.dtype),
            x_prev_cm=rwkv_st0.x_prev_cm)
    elif mixer == "rglru":
        st0 = slot_state(cache["rglru"])
        mix, st = rglrumod.rglru_block(lp["rglru"], hn, return_state=True,
                                       state=st0)
        new_cache["rglru"] = store_state(cache["rglru"], st)
    else:
        raise ValueError(mixer)
    h = h + mix.astype(h.dtype)
    h2 = apply_norm(cfg.norm, h, lp["ln2"])
    if mixer == "rwkv":
        y = rwkvmod.channel_mix(lp["cm"], h2, rwkv_st0.x_prev_cm)
        rwkv_st = rwkvmod.RWKVState(
            s=rwkv_st.s, x_prev_tm=rwkv_st.x_prev_tm,
            x_prev_cm=h2[:, -1].astype(rwkv_st.x_prev_cm.dtype))
        new_cache["rwkv"] = store_state(cache["rwkv"], rwkv_st)
    else:
        y, _ = _ffn_apply(cfg, lp, h2, None, "pfc")
    return h + y.astype(h.dtype), new_cache


def prefill_chunk_paged(cfg: ModelConfig, params: dict, caches: list,
                        tokens: jax.Array, pos0: jax.Array, slot: jax.Array,
                        bt_row: jax.Array, ring_cap: jax.Array,
                        scan: bool = True):
    """One prompt chunk for one request: tokens (1, C) starting at absolute
    position ``pos0`` -> (last-token logits (1, V), new caches).  Interleaves
    with decode steps in the engine loop (chunked prefill)."""
    if cfg.enc_dec:
        raise NotImplementedError(
            "paged serving does not support encoder-decoder archs")
    h = embed_tokens(cfg, params, tokens)
    if cfg.pos == "sinusoidal":
        d = h.shape[-1]
        table = sinusoidal_positions(caches_context(caches, cfg), d)
        c = tokens.shape[1]
        h = h + jax.lax.dynamic_slice_in_dim(table, pos0, c, 0)[None].astype(h.dtype)

    def fn(mixer, lp, hh, cs):
        return layer_prefill_chunk(cfg, mixer, lp, hh, cs, pos0, slot,
                                   bt_row, ring_cap)

    h, new_caches = _apply_layers(cfg, params, caches, h, fn, scan)
    h = apply_norm(cfg.norm, h, params["final_norm"])
    logits = tpmod.gather_cols(linear(params["lm_head"], h[:, -1]), cfg.vocab)
    return logits, new_caches


def caches_context(caches: list, cfg: ModelConfig) -> int:
    """Max positional extent needed for sinusoidal decode tables."""
    for cs in caches:
        leaves = jax.tree.leaves(cs)
        for leaf in leaves:
            if leaf.ndim >= 3:
                return max(2048, leaf.shape[2])
    return 2048
