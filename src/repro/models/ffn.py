"""Feed-forward blocks: fused-gate SwiGLU/GeGLU, plain GELU (whisper),
and the RWKV channel-mix (lives in rwkv6.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import LinearCtx, linear


def glu_ffn(p: dict, x: jax.Array, act: str = "silu",
            ctx: LinearCtx | None = None, name: str = "mlp") -> jax.Array:
    """wi (d, 2f) fuses gate|up; wo (f, d)."""
    gu = linear(p["wi"], x, ctx, f"{name}.wi")
    gate, up = jnp.split(gu, 2, axis=-1)
    g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
    return linear(p["wo"], g * up, ctx, f"{name}.wo")


def gelu_ffn(p: dict, x: jax.Array, ctx: LinearCtx | None = None,
             name: str = "mlp") -> jax.Array:
    """Plain 2-matrix GELU MLP (whisper)."""
    h = jax.nn.gelu(linear(p["wi"], x, ctx, f"{name}.wi"))
    return linear(p["wo"], h, ctx, f"{name}.wo")
