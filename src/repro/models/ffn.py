"""Feed-forward blocks: fused-gate SwiGLU/GeGLU, plain GELU (whisper),
and the RWKV channel-mix (lives in rwkv6.py).

Under tensor-parallel serving (runtime/tp.py) ``wi`` arrives column-sharded
inside ``shard_map`` (gate|up interleaved per shard so the local split is
correct) while ``wo`` stays replicated; ``gather_cols`` reassembles the full
hidden width before the down-projection and is a shape-driven no-op on the
unsharded / TP=1 path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime.tp import gather_cols, in_dim
from .common import LinearCtx, linear


def glu_ffn(p: dict, x: jax.Array, act: str = "silu",
            ctx: LinearCtx | None = None, name: str = "mlp") -> jax.Array:
    """wi (d, 2f) fuses gate|up; wo (f, d)."""
    gu = linear(p["wi"], x, ctx, f"{name}.wi")
    gate, up = jnp.split(gu, 2, axis=-1)
    g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
    h = gather_cols(g * up, in_dim(p["wo"]))
    return linear(p["wo"], h, ctx, f"{name}.wo")


def gelu_ffn(p: dict, x: jax.Array, ctx: LinearCtx | None = None,
             name: str = "mlp") -> jax.Array:
    """Plain 2-matrix GELU MLP (whisper)."""
    h = jax.nn.gelu(linear(p["wi"], x, ctx, f"{name}.wi"))
    h = gather_cols(h, in_dim(p["wo"]))
    return linear(p["wo"], h, ctx, f"{name}.wo")
