"""The composable LM stack: init / forward / loss / prefill / decode for every
assigned architecture, with scan-over-layers (grouped by the mixer pattern's
period) for compile-time sanity at 60-layer scale, and an unrolled mode for
calibration (LinearCtx taps) and tiny-model debugging.

Canonical param layout (also the checkpoint/sharding layout):

  params = {
    "embed":      (V, d),
    "layers":     [stack_0, ..., stack_{p-1}],   # p = cfg.scan_period
    "enc_layers": [stack_0]                      # whisper only
    "final_norm": {...}, ["enc_norm": {...}],
    "lm_head":    (d, V),
  }

``layers[j]`` stacks every layer with index = j (mod p) along a leading axis
(n_j entries).  Execution order i = 0..L-1 maps to (stack i % p, element
i // p); lax.scan runs the first L // p full periods, the remainder is
unrolled.  Homogeneous models (p = 1) reduce to one stack of L.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attnmod
from . import ffn as ffnmod
from . import mla as mlamod
from . import moe as moemod
from . import rglru as rglrumod
from . import rwkv6 as rwkvmod
from .common import (LinearCtx, apply_mrope, apply_norm, apply_rope,
                     cross_entropy, dense_init, linear, norm_params, rms_norm,
                     sinusoidal_positions, split_keys)
from .config import ModelConfig

# ============================================================ initialization


def _init_attn(cfg: ModelConfig, key, dtype, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = split_keys(key, 4)
    p = {"wq": dense_init(ks[0], d, h * hd, dtype),
         "wk": dense_init(ks[1], d, kv * hd, dtype),
         "wv": dense_init(ks[2], d, kv * hd, dtype),
         "wo": dense_init(ks[3], h * hd, d, dtype, scale=(h * hd) ** -0.5)}
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _init_mla(cfg: ModelConfig, key, dtype) -> dict:
    m = cfg.mla
    d = cfg.d_model
    ks = split_keys(key, 5)
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora, dtype),
        "q_norm": jnp.ones((m.q_lora,), jnp.float32),
        "wq_b": dense_init(ks[1], m.q_lora, m.n_heads * (m.qk_nope + m.qk_rope), dtype),
        "wkv_a": dense_init(ks[2], d, m.kv_lora + m.qk_rope, dtype),
        "kv_norm": jnp.ones((m.kv_lora,), jnp.float32),
        "wkv_b": dense_init(ks[3], m.kv_lora, m.n_heads * (m.qk_nope + m.v_head), dtype),
        "wo": dense_init(ks[4], m.n_heads * m.v_head, d, dtype,
                         scale=(m.n_heads * m.v_head) ** -0.5),
    }


def _init_ffn(cfg: ModelConfig, key, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    if cfg.ffn_kind() == "gelu":
        return {"wi": dense_init(k1, d, f, dtype),
                "wo": dense_init(k2, f, d, dtype, scale=f ** -0.5)}
    return {"wi": dense_init(k1, d, 2 * f, dtype),
            "wo": dense_init(k2, f, d, dtype, scale=f ** -0.5)}


def _init_moe(cfg: ModelConfig, key, dtype) -> dict:
    mo = cfg.moe
    d, fe = cfg.d_model, mo.d_ff_expert
    ks = split_keys(key, 5)
    p = {"router": dense_init(ks[0], d, mo.n_experts, jnp.float32),
         "wi": (jax.random.normal(ks[1], (mo.n_experts, d, 2 * fe), jnp.float32)
                * d ** -0.5).astype(dtype),
         "wo": (jax.random.normal(ks[2], (mo.n_experts, fe, d), jnp.float32)
                * fe ** -0.5).astype(dtype)}
    if mo.n_shared:
        fs = fe * mo.n_shared
        p["swi"] = dense_init(ks[3], d, 2 * fs, dtype)
        p["swo"] = dense_init(ks[4], fs, d, dtype, scale=fs ** -0.5)
    return p


def _init_rwkv_tm(cfg: ModelConfig, key, dtype) -> dict:
    d = cfg.d_model
    h, dk = cfg.n_heads, cfg.hd
    ks = split_keys(key, 10)
    return {
        "mu_x": jnp.full((d,), 0.5, jnp.float32),
        "mu_rkvwg": jnp.full((5, d), 0.5, jnp.float32),
        "tm_w1": dense_init(ks[0], d, 5 * rwkvmod.LORA_R, dtype, scale=1e-2),
        "tm_w2": (jax.random.normal(ks[1], (5, rwkvmod.LORA_R, d), jnp.float32)
                  * 1e-2).astype(dtype),
        "w0": jnp.zeros((d,), jnp.float32),
        "dw_a": dense_init(ks[2], d, rwkvmod.DECAY_R, dtype, scale=1e-2),
        "dw_b": dense_init(ks[3], rwkvmod.DECAY_R, d, dtype, scale=1e-2),
        "u": jnp.zeros((h, dk), jnp.float32),
        "ln_x": jnp.ones((d,), jnp.float32),
        "wr": dense_init(ks[4], d, d, dtype),
        "wk": dense_init(ks[5], d, d, dtype),
        "wv": dense_init(ks[6], d, d, dtype),
        "wg": dense_init(ks[7], d, d, dtype),
        "wo": dense_init(ks[8], d, d, dtype),
    }


def _init_rwkv_cm(cfg: ModelConfig, key, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    return {"mu_k": jnp.full((d,), 0.5, jnp.float32),
            "mu_r": jnp.full((d,), 0.5, jnp.float32),
            "ck": dense_init(ks[0], d, f, dtype),
            "cv": dense_init(ks[1], f, d, dtype, scale=f ** -0.5),
            "cr": dense_init(ks[2], d, d, dtype)}


def _init_rglru(cfg: ModelConfig, key, dtype) -> dict:
    d = cfg.d_model
    dr = cfg.rglru_width or d
    nb = cfg.n_heads
    bs = dr // nb
    ks = split_keys(key, 5)
    return {
        "wg": dense_init(ks[0], d, dr, dtype),
        "wi": dense_init(ks[1], d, dr, dtype),
        "conv_w": (jax.random.normal(ks[2], (rglrumod.CONV_WIDTH, dr),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dr,), jnp.float32),
        "wa": (jax.random.normal(ks[3], (nb, bs, bs), jnp.float32)
               * bs ** -0.5).astype(dtype),
        "ba": jnp.full((dr,), 2.0, jnp.float32),   # bias toward remembering
        "bx": jnp.zeros((dr,), jnp.float32),
        "wx": (jax.random.normal(ks[4], (nb, bs, bs), jnp.float32)
               * bs ** -0.5).astype(dtype),
        "lambda": jnp.linspace(2.0, 5.0, dr, dtype=jnp.float32),
        "wo": dense_init(jax.random.fold_in(key, 7), dr, d, dtype,
                         scale=dr ** -0.5),
    }


def _init_layer(cfg: ModelConfig, mixer: str, key, dtype,
                cross: bool = False, encoder: bool = False) -> dict:
    k1, k2, k3 = split_keys(key, 3)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1": norm_params(cfg.norm, d),
                         "ln2": norm_params(cfg.norm, d)}
    if mixer == "attn":
        p["attn"] = _init_attn(cfg, k1, dtype)
    elif mixer == "mla":
        p["mla"] = _init_mla(cfg, k1, dtype)
    elif mixer == "rwkv":
        p["tm"] = _init_rwkv_tm(cfg, k1, dtype)
    elif mixer == "rglru":
        p["rglru"] = _init_rglru(cfg, k1, dtype)
    else:
        raise ValueError(mixer)
    fk = cfg.ffn_kind()
    if fk == "moe":
        p["moe"] = _init_moe(cfg, k2, dtype)
    elif fk == "cm":
        p["cm"] = _init_rwkv_cm(cfg, k2, dtype)
    else:
        p["mlp"] = _init_ffn(cfg, k2, dtype)
    if cross:
        p["ln_x"] = norm_params(cfg.norm, d)
        p["xattn"] = _init_attn(cfg, k3, dtype, cross=True)
    return p


def _stack(trees: list) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    pat = cfg.pattern
    p_period = cfg.scan_period
    keys = split_keys(key, cfg.n_layers + cfg.n_enc_layers + 3)
    per_layer = [_init_layer(cfg, pat[i], keys[i], dtype,
                             cross=cfg.enc_dec) for i in range(cfg.n_layers)]
    stacks = []
    for j in range(p_period):
        stacks.append(_stack(per_layer[j::p_period]))
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[-1], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "layers": stacks,
        "final_norm": norm_params(cfg.norm, cfg.d_model),
        "lm_head": dense_init(keys[-2], cfg.d_model, cfg.vocab, dtype),
    }
    if cfg.enc_dec:
        enc_layers = [_init_layer(cfg, "attn", keys[cfg.n_layers + i], dtype,
                                  encoder=True) for i in range(cfg.n_enc_layers)]
        params["enc_layers"] = [_stack(enc_layers)]
        params["enc_norm"] = norm_params(cfg.norm, cfg.d_model)
    return params


# ================================================================== blocks


def _qk_normalize(p: dict, q, k):
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k


def _attn_seq(cfg: ModelConfig, p: dict, x: jax.Array, positions,
              ctx, name, *, causal=True, window=None, kv_src=None,
              use_rope=True) -> jax.Array:
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    src = x if kv_src is None else kv_src
    sk = src.shape[1]
    q = linear(p["wq"], x, ctx, f"{name}.wq").reshape(b, s, h, hd)
    k = linear(p["wk"], src, ctx, f"{name}.wk").reshape(b, sk, kv, hd)
    v = linear(p["wv"], src, ctx, f"{name}.wv").reshape(b, sk, kv, hd)
    q, k = _qk_normalize(p, q, k)
    if use_rope and cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif use_rope and cfg.pos == "mrope":
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    out = attnmod.flash_attention(q, k, v, causal=causal, window=window,
                                  remat_chunks=cfg.remat_attention,
                                  expand_kv=cfg.expand_kv)
    return linear(p["wo"], out.reshape(b, s, h * hd), ctx, f"{name}.wo")


def _ffn_apply(cfg: ModelConfig, lp: dict, h2: jax.Array, ctx, name):
    fk = cfg.ffn_kind()
    if fk == "moe":
        return moemod.moe_ffn(lp["moe"], h2, n_experts=cfg.moe.n_experts,
                              top_k=cfg.moe.top_k,
                              capacity_factor=cfg.moe.capacity_factor,
                              act=cfg.act, ctx=ctx, name=f"{name}.moe")
    if fk == "cm":
        return rwkvmod.channel_mix(lp["cm"], h2, None, ctx, f"{name}.cm"), 0.0
    if fk == "gelu":
        return ffnmod.gelu_ffn(lp["mlp"], h2, ctx, f"{name}.mlp"), 0.0
    return ffnmod.glu_ffn(lp["mlp"], h2, act=cfg.act, ctx=ctx,
                          name=f"{name}.mlp"), 0.0


def layer_seq(cfg: ModelConfig, mixer: str, lp: dict, h: jax.Array,
              positions, ctx=None, name: str = "layer",
              encoder_out: jax.Array | None = None, causal: bool = True):
    """One full layer in sequence mode. Returns (h, aux_loss)."""
    hn = apply_norm(cfg.norm, h, lp["ln1"])
    if mixer == "attn":
        window = cfg.window if causal else None
        mix = _attn_seq(cfg, lp["attn"], hn, positions, ctx, f"{name}.attn",
                        causal=causal, window=window)
    elif mixer == "mla":
        mix = mlamod.mla_full(lp["mla"], hn, cfg.mla, positions, ctx,
                              f"{name}.mla",
                              remat_chunks=cfg.remat_attention)
    elif mixer == "rwkv":
        mix = rwkvmod.time_mix(lp["tm"], hn, n_heads=cfg.n_heads,
                               head_dim=cfg.hd, ctx=ctx, name=f"{name}.tm")
    elif mixer == "rglru":
        mix = rglrumod.rglru_block(lp["rglru"], hn, ctx, f"{name}.rglru")
    else:
        raise ValueError(mixer)
    h = h + mix.astype(h.dtype)
    if encoder_out is not None:
        hx = apply_norm(cfg.norm, h, lp["ln_x"])
        h = h + _attn_seq(cfg, lp["xattn"], hx, positions, ctx,
                          f"{name}.xattn", causal=False, kv_src=encoder_out,
                          use_rope=False)
    h2 = apply_norm(cfg.norm, h, lp["ln2"])
    y, aux = _ffn_apply(cfg, lp, h2, ctx, name)
    from repro.runtime.actsharding import shard_hidden
    return shard_hidden(h + y.astype(h.dtype)), aux


# ================================================================ forward


def get_layer(params: dict, jpos: int, idx: int):
    """Layer params: stacked tree (fp training) or python list (quantized
    models with heterogeneous per-layer bit widths)."""
    st = params["layers"][jpos]
    if isinstance(st, list):
        return st[idx]
    return jax.tree.map(lambda a: a[idx], st)


def layers_scannable(params: dict) -> bool:
    return not any(isinstance(st, list) for st in params["layers"])


def embed_tokens(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embed"], tokens, axis=0)


def _default_positions(cfg: ModelConfig, b: int, s: int, offset=0):
    pos = offset + jnp.arange(s, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.pos == "mrope":
        return jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def encode(cfg: ModelConfig, params: dict, enc_embeds: jax.Array,
           ctx=None, scan: bool = True) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, T, d)."""
    b, t, d = enc_embeds.shape
    h = enc_embeds + sinusoidal_positions(t, d).astype(enc_embeds.dtype)[None]
    stack = params["enc_layers"][0]
    if isinstance(stack, list):
        scan, n = False, len(stack)
    else:
        n = jax.tree.leaves(stack)[0].shape[0]
    if scan:
        def body(carry, lp):
            hh, aux = carry
            hh, a = layer_seq(cfg, "attn", lp, hh, None, None, "enc",
                              causal=False)
            return (hh, aux + a), None
        (h, _), _ = jax.lax.scan(body, (h, 0.0), stack)
    else:
        for i in range(n):
            lp = (stack[i] if isinstance(stack, list)
                  else jax.tree.map(lambda a: a[i], stack))
            h, _ = layer_seq(cfg, "attn", lp, h, None, ctx, f"enc{i}",
                             causal=False)
    return apply_norm(cfg.norm, h, params["enc_norm"])


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array | None = None,
            *, embeds: jax.Array | None = None, positions=None,
            encoder_out: jax.Array | None = None,
            enc_embeds: jax.Array | None = None,
            ctx: Optional[LinearCtx] = None, scan: bool = True):
    """Sequence-mode forward -> (logits (B, S, V), aux_loss)."""
    h = embeds if embeds is not None else embed_tokens(cfg, params, tokens)
    b, s, d = h.shape
    if cfg.enc_dec and encoder_out is None:
        assert enc_embeds is not None, "whisper needs encoder frames"
        encoder_out = encode(cfg, params, enc_embeds, ctx=ctx, scan=scan)
    if cfg.pos == "sinusoidal":
        h = h + sinusoidal_positions(s, d).astype(h.dtype)[None]
    if positions is None:
        positions = _default_positions(cfg, b, s)

    pat = cfg.pattern
    p_period = cfg.scan_period
    stacks = params["layers"]
    n_full = cfg.n_layers // p_period
    rem = cfg.n_layers % p_period
    aux_total = jnp.float32(0.0)
    scan = scan and layers_scannable(params)

    if scan and n_full > 0:
        full_stacks = [jax.tree.map(lambda a: a[:n_full], st) for st in stacks]

        def body(carry, lps):
            hh, aux = carry
            for j in range(p_period):
                hh, a = layer_seq(cfg, pat[j], lps[j], hh, positions, None,
                                  "blk", encoder_out=encoder_out)
                aux = aux + a
            return (hh, aux), None

        (h, aux_total), _ = jax.lax.scan(body, (h, aux_total),
                                         tuple(full_stacks))
        for j in range(rem):
            lp = jax.tree.map(lambda a: a[n_full], stacks[j])
            h, a = layer_seq(cfg, pat[j], lp, h, positions, None,
                             f"rem{j}", encoder_out=encoder_out)
            aux_total = aux_total + a
    else:
        for i in range(cfg.n_layers):
            jpos, idx = i % p_period, i // p_period
            lp = get_layer(params, jpos, idx)
            h, a = layer_seq(cfg, pat[i], lp, h, positions, ctx, f"L{i}",
                             encoder_out=encoder_out)
            aux_total = aux_total + a
    h = apply_norm(cfg.norm, h, params["final_norm"])
    logits = linear(params["lm_head"], h, ctx, "lm_head")
    return logits, aux_total


def loss_fn(cfg: ModelConfig, params: dict, batch: dict,
            ctx: Optional[LinearCtx] = None, scan: bool = True) -> jax.Array:
    """Mean next-token NLL (+ MoE aux).  batch: tokens (B, S+1) [+ extras]."""
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    positions = batch.get("positions")
    if positions is not None:
        positions = positions[..., : inputs.shape[1]]
    logits, aux = forward(cfg, params, inputs, positions=positions,
                          enc_embeds=batch.get("enc_embeds"),
                          embeds=batch.get("embeds"), ctx=ctx, scan=scan)
    loss = cross_entropy(logits, labels, batch.get("mask"))
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_coef * aux
    return loss
