"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP-friendly).

Dispatch = argsort token->expert assignments by expert id, rank each token
within its expert (cumulative count), scatter into an (E, C, d) buffer, run
grouped expert GEMMs, and combine with the routing weights.  No (T, E, C)
one-hot is ever materialized (GShard-style einsum dispatch would be ~GBs at
160 experts); under GSPMD the (E, C, d) buffer is sharded on the expert axis,
so the scatter/gather lower to the all-to-all-ish collectives of expert
parallelism.  Tokens past capacity are dropped (standard top-k capacity
semantics); an aux load-balance loss is returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.qlinear import QuantizedGrouped
from repro.runtime.tp import gather_cols
from .common import LinearCtx, linear


def _expert_matmul(w, xbuf: jax.Array, ctx: LinearCtx | None = None,
                   name: str | None = None) -> jax.Array:
    """Grouped GEMM (E,C,d)x(E,d,f) with QuantizedGrouped dispatch and the
    same calibration taps/perturbations as ``common.linear``.  Quantized
    experts go through the fused RHT+qmatmul kernel vmapped over E — per-
    expert codes stay packed; no dense (E, d, f) dequant buffer exists."""
    if isinstance(w, QuantizedGrouped):
        return w.apply(xbuf).astype(xbuf.dtype)
    y = jnp.einsum("ecd,edf->ecf", xbuf, w.astype(xbuf.dtype))
    if ctx is not None and name is not None:
        if ctx.collect:
            xf = xbuf.astype(jnp.float32)
            ctx.taps[name] = dict(
                x_fro_sq=jnp.sum(xf * xf),
                x_col_sq=jnp.sum(xf * xf, axis=(0, 1)),
                w_fro=jnp.linalg.norm(w.astype(jnp.float32)),
                n_rows=jnp.asarray(xbuf.shape[0] * xbuf.shape[1], jnp.float32),
                d=w.shape[1], c=w.shape[2], h_shape=y.shape, grouped=True,
                n_groups=w.shape[0])
        if ctx.perturb is not None and name in ctx.perturb:
            y = y + ctx.perturb[name].astype(y.dtype)
    return y


def moe_ffn(p: dict, x: jax.Array, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25, act: str = "silu",
            ctx: LinearCtx | None = None, name: str = "moe"):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    Params: router (d, E) fp32; wi (E, d, 2f); wo (E, f, d);
    optional shared experts: swi (d, 2fs), swo (fs, d).
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)                  # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- load-balance aux loss (Switch-style) ---
    me = jnp.mean(probs, axis=0)                                         # (E,)
    ce = jnp.zeros((n_experts,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (t * top_k))
    aux = n_experts * jnp.sum(me * ce)

    # --- sort-based dispatch ---
    capacity = int(max(top_k, capacity_factor * t * top_k / n_experts))
    flat_expert = expert_ids.reshape(-1)                                 # (T*K,)
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    e_sorted = flat_expert[order]
    t_sorted = flat_token[order]
    g_sorted = flat_gate[order]
    # rank within expert = index - start offset of that expert's run
    counts = jnp.zeros((n_experts,), jnp.int32).at[e_sorted].add(1)
    starts = jnp.cumsum(counts) - counts                                 # (E,)
    rank = jnp.arange(t * top_k, dtype=jnp.int32) - starts[e_sorted]
    keep = rank < capacity
    slot = jnp.where(keep, rank, capacity)                               # overflow row
    xbuf = jnp.zeros((n_experts, capacity + 1, d), xf.dtype)
    xbuf = xbuf.at[e_sorted, slot].add(
        jnp.where(keep[:, None], xf[t_sorted], 0.0).astype(xf.dtype))
    xbuf = xbuf[:, :capacity]                                            # (E, C, d)

    # --- grouped expert GEMMs ---
    gu = _expert_matmul(p["wi"], xbuf, ctx, f"{name}.wi")
    gate_h, up = jnp.split(gu, 2, axis=-1)
    h = (jax.nn.silu(gate_h) if act == "silu" else jax.nn.gelu(gate_h)) * up
    # TP (runtime/tp.py): wi is column-sharded per expert, wo replicated —
    # reassemble the full expert hidden width (no-op when unsharded).
    h = gather_cols(h, p["wo"].shape[1])
    ybuf = _expert_matmul(p["wo"], h, ctx, f"{name}.wo")                 # (E, C, d)

    # --- combine ---
    gathered = ybuf[e_sorted, jnp.minimum(slot, capacity - 1)]           # (T*K, d)
    contrib = jnp.where(keep[:, None], gathered * g_sorted[:, None].astype(
        gathered.dtype), 0.0)
    y = jnp.zeros((t, d), xf.dtype).at[t_sorted].add(contrib.astype(xf.dtype))

    # --- shared experts (DeepSeek-V2) ---
    if "swi" in p:
        gu_s = linear(p["swi"], xf, ctx, f"{name}.swi")
        gsh, ush = jnp.split(gu_s, 2, axis=-1)
        hs = (jax.nn.silu(gsh) if act == "silu" else jax.nn.gelu(gsh)) * ush
        hs = gather_cols(hs, p["swo"].shape[0])
        y = y + linear(p["swo"], hs, ctx, f"{name}.swo")
    return y.reshape(b, s, d), aux
