"""Byte-level tokenizer (vocab 256 + special ids folded by modulo for smaller
model vocabs). No external vocab files — fully offline."""
from __future__ import annotations

import numpy as np


class ByteTokenizer:
    def __init__(self, vocab: int = 256):
        self.vocab = vocab

    def encode(self, text: str) -> np.ndarray:
        toks = np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)
        if self.vocab < 256:
            toks = toks % self.vocab
        return toks

    def decode(self, toks) -> str:
        return bytes(int(t) % 256 for t in toks).decode("utf-8", errors="replace")
