from .loader import LMBatchLoader, make_corpus_tokens  # noqa: F401
from .tokenizer import ByteTokenizer  # noqa: F401
