"""Deterministic synthetic corpus: a seeded template-grammar text generator.

Produces structured English-like text with learnable statistics (fixed word
inventory, grammar templates, punctuation, rare-token tail) so that a small
LM trained for a few hundred steps develops real next-token structure — which
is what the quantization quality benchmarks need to measure perplexity deltas
against.  Fully offline and reproducible.
"""
from __future__ import annotations

import numpy as np

_SUBJECTS = ("the fox", "a raven", "the quiet stream", "an old engineer",
             "the compiler", "a careful reader", "the tensor", "the machine",
             "a curious child", "the gardener", "the signal", "an open door")
_VERBS = ("leaped over", "watched", "compiled", "measured", "followed",
          "rewrote", "balanced", "sharded", "quantized", "traced",
          "remembered", "repaired")
_OBJECTS = ("the golden light", "a distant hill", "the long array",
            "its own reflection", "the morning fog", "a stack of pages",
            "the second stream", "a row of numbers", "the floating point",
            "the silent yard", "an even lattice", "the narrow bridge")
_ADVERBS = ("slowly", "twice", "without error", "in the afternoon",
            "with great care", "again", "almost silently", "by hand")


def generate_text(n_sentences: int, seed: int = 0) -> str:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_sentences):
        s = rng.choice(_SUBJECTS)
        v = rng.choice(_VERBS)
        o = rng.choice(_OBJECTS)
        parts = [s, v, o]
        if rng.random() < 0.5:
            parts.append(rng.choice(_ADVERBS))
        sent = " ".join(parts) + ". "
        if rng.random() < 0.1:
            sent = sent.capitalize()
        out.append(sent)
    return "".join(out)
