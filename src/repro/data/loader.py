"""Packed LM batch pipeline: corpus -> token stream -> (B, S+1) batches,
deterministically sharded per data-parallel host group."""
from __future__ import annotations

import numpy as np

from .synthetic import generate_text
from .tokenizer import ByteTokenizer


def make_corpus_tokens(vocab: int, n_sentences: int = 20000,
                       seed: int = 0) -> np.ndarray:
    return ByteTokenizer(vocab).encode(generate_text(n_sentences, seed))


class LMBatchLoader:
    """Infinite iterator of next-token-prediction batches.

    Supports deterministic resume (state = step counter) and host sharding
    (host i of n draws disjoint strided windows) — the loader side of elastic
    restart: any (step, host_count) pair maps to the same global sample set.
    """

    def __init__(self, tokens: np.ndarray, batch: int, seq_len: int,
                 host_index: int = 0, host_count: int = 1, seed: int = 17):
        self.tokens = np.asarray(tokens, dtype=np.int32)
        self.batch = batch
        self.seq = seq_len
        self.host_index = host_index
        self.host_count = host_count
        self.seed = seed
        self.step = 0
        if len(self.tokens) < seq_len + 2:
            raise ValueError("corpus too small for seq_len")

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, st: dict) -> None:
        self.step = int(st["step"])

    def next_batch(self) -> np.ndarray:
        rng = np.random.default_rng(
            (self.seed, self.step, self.host_index, self.host_count))
        hi = len(self.tokens) - self.seq - 1
        starts = rng.integers(0, hi, size=self.batch)
        out = np.stack([self.tokens[s: s + self.seq + 1] for s in starts])
        self.step += 1
        return out

    def eval_batches(self, n: int, batch: int | None = None):
        """Deterministic held-out-style windows for perplexity eval."""
        batch = batch or self.batch
        rng = np.random.default_rng((self.seed, 10 ** 9))
        hi = len(self.tokens) - self.seq - 1
        for _ in range(n):
            starts = rng.integers(0, hi, size=batch)
            yield np.stack([self.tokens[s: s + self.seq + 1] for s in starts])
