"""Pure-jnp oracle for the RaBitQ code-search kernel = repro.core.rabitq."""
from __future__ import annotations

import jax

from repro.core import rabitq


def quantize_ref(w: jax.Array, bits: int, n_candidates: int = 12):
    q = rabitq.quantize(w, bits, n_candidates=n_candidates)
    return q.codes, q.rescale
