"""Dispatching wrapper for the RaBitQ code-search kernel."""
from __future__ import annotations

import jax

from repro.core.rabitq import RabitqCodes
from .quantize import quantize_pallas
from .ref import quantize_ref

_FORCE_PATH: str | None = None


def set_forced_path(path: str | None) -> None:
    global _FORCE_PATH
    assert path in (None, "pallas", "ref")
    _FORCE_PATH = path


def quantize(w: jax.Array, bits: int, n_candidates: int = 12) -> RabitqCodes:
    path = _FORCE_PATH
    if path is None:
        path = "pallas" if jax.default_backend() == "tpu" else "ref"
    if path == "pallas":
        codes, rescale = quantize_pallas(
            w, bits=bits, n_candidates=n_candidates,
            interpret=jax.default_backend() != "tpu")
    else:
        codes, rescale = quantize_ref(w, bits, n_candidates)
    return RabitqCodes(codes=codes, rescale=rescale, bits=bits)
