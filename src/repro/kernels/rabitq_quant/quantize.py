"""Pallas TPU kernel: extended-RaBitQ code search + LS rescale, per column.

Grid over output-column tiles; each kernel instance holds a full (d, bc)
column slab in VMEM and runs the S-candidate grid-step sweep entirely
on-chip (reductions over d on the VPU), then emits codes + the closed-form
least-squares rescale.  The sweep is unrolled (S is static and small), so the
compiler can keep w and the running best in registers/VMEM — the CPU-bound
per-vector search of the reference implementation becomes one pass of
vector work per slab.

VMEM budget: ~3 slabs of (d, bc) f32 (w, v, best-v bookkeeping); ops.py picks
bc so that stays under ~8 MB even at d = 20480.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, scales_ref, codes_ref, rescale_ref, *, bits: int, n_cand: int):
    w = w_ref[...].astype(jnp.float32)                    # (d, bc)
    levels = float((1 << bits) - 1)
    c_b = levels / 2.0
    absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True)   # (1, bc)
    delta0 = jnp.maximum(absmax, 1e-30) / c_b
    best_err = jnp.full(absmax.shape, jnp.inf, jnp.float32)
    best_delta = delta0
    for s in range(n_cand):
        delta = delta0 * scales_ref[0, s]
        v = jnp.clip(jnp.round(w / delta + c_b), 0.0, levels) - c_b
        wv = jnp.sum(w * v, axis=0, keepdims=True)
        vv = jnp.maximum(jnp.sum(v * v, axis=0, keepdims=True), 1e-30)
        err = -(wv * wv) / vv
        take = err < best_err
        best_err = jnp.where(take, err, best_err)
        best_delta = jnp.where(take, delta, best_delta)
    v = jnp.clip(jnp.round(w / best_delta + c_b), 0.0, levels) - c_b
    wv = jnp.sum(w * v, axis=0, keepdims=True)
    vv = jnp.maximum(jnp.sum(v * v, axis=0, keepdims=True), 1e-30)
    codes_ref[...] = (v + c_b).astype(jnp.uint8)
    rescale_ref[...] = jnp.where(vv > 1e-29, wv / vv, 0.0).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "n_candidates", "bc",
                                             "interpret"))
def quantize_pallas(w: jax.Array, *, bits: int, n_candidates: int = 12,
                    lo: float = 0.3, hi: float = 1.05, bc: int | None = None,
                    interpret: bool = True):
    """Quantize columns of w (d, c): returns (codes uint8 (d, c), rescale (c,))."""
    d, c = w.shape
    if bc is None:
        bc = max(8, min(128, (8 * 1024 * 1024 // 12) // max(d, 1)))
    c_pad = pl.cdiv(c, bc) * bc
    wp = jnp.zeros((d, c_pad), jnp.float32).at[:, :c].set(w.astype(jnp.float32))
    scales = jnp.geomspace(lo, hi, n_candidates, dtype=jnp.float32).reshape(1, -1)
    codes, rescale = pl.pallas_call(
        functools.partial(_kernel, bits=bits, n_cand=n_candidates),
        grid=(c_pad // bc,),
        in_specs=[
            pl.BlockSpec((d, bc), lambda j: (0, j)),
            pl.BlockSpec((1, n_candidates), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d, bc), lambda j: (0, j)),
            pl.BlockSpec((1, bc), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, c_pad), jnp.uint8),
            jax.ShapeDtypeStruct((1, c_pad), jnp.float32),
        ],
        interpret=interpret,
    )(wp, scales)
    return codes[:, :c], rescale[0, :c]
