"""Dispatching wrapper for the RHT kernel (practical-RHT composition included)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hadamard as hcore
from .hadamard import rht_pallas

_FORCE_PATH: str | None = None


def set_forced_path(path: str | None) -> None:
    global _FORCE_PATH
    assert path in (None, "pallas", "ref")
    _FORCE_PATH = path


def _rht_block(x2: jax.Array, signs: jax.Array) -> jax.Array:
    path = _FORCE_PATH
    if path is None:
        path = "pallas" if jax.default_backend() == "tpu" else "ref"
    if path == "pallas":
        return rht_pallas(x2, signs, interpret=jax.default_backend() != "tpu")
    return hcore.rht(x2, signs, axis=-1)


def practical_rht(x: jax.Array, signs1: jax.Array, signs2: jax.Array | None
                  ) -> jax.Array:
    """Paper Alg. 5 over the last axis of x (..., d), any d, kernel-backed."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    d_hat = hcore.largest_pow2_leq(d)
    y = x2.at[:, :d_hat].set(_rht_block(x2[:, :d_hat], signs1))
    if d_hat != d:
        y = y.at[:, d - d_hat:].set(_rht_block(y[:, d - d_hat:], signs2))
    return y.reshape(*lead, d)
