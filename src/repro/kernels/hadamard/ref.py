"""Pure-jnp oracle for the RHT kernel."""
from __future__ import annotations

import jax

from repro.core import hadamard as hcore


def rht_ref(x: jax.Array, signs: jax.Array) -> jax.Array:
    """Hadamard(D x) row-wise for x (n, d), d a power of 2."""
    return hcore.rht(x, signs, axis=-1)
