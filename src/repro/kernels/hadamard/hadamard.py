"""Pallas TPU kernel: randomized Hadamard transform as two MXU matmuls.

Kronecker factorization H_{d1*d2} = H_{d1} (x) H_{d2} turns a length-d FWHT
into: reshape the VMEM-resident (bn, d) row tile to (bn, d1, d2), contract
H_{d2} on the last axis and H_{d1} on the middle axis — both dense matmuls
with small orthonormal Hadamard matrices (<= 256x256), i.e. exactly MXU work.
No HBM round-trip between the two stages, unlike a literal log(d)-stage
butterfly port (which would be VPU-bound and relayout every stage).

The Rademacher sign flip is fused as a pre-multiply on the input tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hadamard import _split_dim, hadamard_matrix


def _kernel(x_ref, signs_ref, h1_ref, h2_ref, out_ref, *, d1: int, d2: int):
    x = x_ref[...] * signs_ref[...]                     # (bn, d) fused D
    bn = x.shape[0]
    xr = x.reshape(bn * d1, d2)
    xr = jnp.dot(xr, h2_ref[...], preferred_element_type=jnp.float32)  # H_{d2}
    xr = xr.reshape(bn, d1, d2).swapaxes(1, 2).reshape(bn * d2, d1)
    xr = jnp.dot(xr, h1_ref[...], preferred_element_type=jnp.float32)  # H_{d1}
    xr = xr.reshape(bn, d2, d1).swapaxes(1, 2).reshape(bn, d1 * d2)
    out_ref[...] = xr.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def rht_pallas(x: jax.Array, signs: jax.Array, *, bn: int = 8,
               interpret: bool = True) -> jax.Array:
    """Hadamard(D x) for x (n, d) with d a power of 2 (rows independent)."""
    n, d = x.shape
    if d & (d - 1):
        raise ValueError(f"rht_pallas requires power-of-2 d, got {d}")
    d1, d2 = _split_dim(d)
    h1 = hadamard_matrix(d1)  # symmetric, so no transpose bookkeeping
    h2 = hadamard_matrix(d2)
    n_pad = pl.cdiv(n, bn) * bn
    xp = jnp.zeros((n_pad, d), x.dtype).at[:n].set(x)
    out = pl.pallas_call(
        functools.partial(_kernel, d1=d1, d2=d2),
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((d1, d1), lambda i: (0, 0)),
            pl.BlockSpec((d2, d2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        interpret=interpret,
    )(xp, signs.reshape(1, d).astype(x.dtype), h1, h2)
    return out[:n]
