"""Dense gather reference for paged attention.

The pre-kernel serving path, generalized to ``W >= 1`` queries per request:
gather every request's blocks into a dense ``(B, MB*bs, KV, hd)`` copy
(``paged_gather_kv``) and run a masked softmax with validity derived from
each slot's stored absolute position (``paged_slot_positions``).  This is
both the CPU/dryrun serving path and the oracle the property-based parity
harness (tests/test_paged_attention_kernel.py) checks the Pallas kernel
against; at W=1 it reproduces the original ``paged_decode_attention`` math
(the extra causal term ``stored <= qpos`` is vacuous there, since every
stored position precedes the single query).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (NEG_INF, paged_gather_kv,
                                    paged_slot_positions)


def paged_attention_ref(q: jax.Array, k_arena: jax.Array, v_arena: jax.Array,
                        block_table: jax.Array, pos: jax.Array,
                        ring_cap: jax.Array, *,
                        window: int | None = None) -> jax.Array:
    """Same contract as ``paged_attention_pallas``: q (B, W, H, hd), arenas
    (N, bs, KV, hd), block_table (B, MB), pos (B,) tokens inserted including
    the last query, ring_cap (B,) -> (B, W, H, hd)."""
    b, w, h, hd = q.shape
    k = paged_gather_kv(k_arena, block_table)       # (B, L, KV, hd)
    v = paged_gather_kv(v_arena, block_table)
    length, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).astype(k.dtype)
    qf = qf.reshape(b, w, kv, g, hd)
    s = jnp.einsum("bwkgd,bskd->bkgws", qf, k,
                   preferred_element_type=jnp.float32)      # (b,kv,g,W,L)
    stored = paged_slot_positions(pos, ring_cap, length)    # (b, L)
    qpos = (pos[:, None] - w) + jnp.arange(w, dtype=jnp.int32)[None]  # (b, W)
    valid = ((stored >= 0)[:, None, :]
             & (stored[:, None, :] <= qpos[:, :, None]))    # (b, W, L)
    if window is not None:
        valid &= (qpos[:, :, None] - stored[:, None, :]) < window
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgws,bskd->bwkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, w, h, hd).astype(q.dtype)
