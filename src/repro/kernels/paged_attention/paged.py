"""Pallas TPU kernel: flash-decoding attention over the paged KV block arena.

The serving decode path used to gather every request's K/V blocks into a
dense ``(B, MB*bs, KV, hd)`` copy before a pure-jnp softmax, so attention
bytes scaled with the block-table *width* even for short requests.  This
kernel reads arena blocks in place: the per-request block table is a
scalar-prefetch operand, so the K/V ``BlockSpec`` index maps chase it —
grid step ``(b, h, j)`` DMAs physical block ``block_table[b, j]`` of KV
head ``h`` straight from the arena into VMEM, and the ``(bq=W*G, bs)``
score tile, online-softmax stats, and output accumulator never leave VMEM.

Layout choices (mirroring ``kernels/flash_attention/flash.py``):

  * GQA via index map: queries are regrouped to ``(B, KV, W*G, hd)`` so one
    grid step serves all G query heads sharing KV head ``h`` — the K/V
    arena is never expanded to H heads in HBM.
  * Ring/window masks are computed in-kernel from stored absolute
    positions (the ``paged_slot_positions`` semantics): slot ``s`` of a
    request with ``cnt`` inserted tokens and ring capacity ``cap`` holds
    position ``last - ((last - s) % cap)`` with ``last = cnt - 1``; a slot
    is a valid key for the query at ``qpos`` iff it was ever written
    (``stored >= 0`` and ``s < cap``), is causally visible
    (``stored <= qpos``), and sits inside the sliding window.
  * Never-written trailing blocks are skipped: ``nblk[b]`` (the number of
    logical blocks actually holding keys) gates the compute with
    ``pl.when``, and the index map clamps ``j`` to ``nblk[b] - 1`` so the
    skipped steps re-address the previous block and no fresh DMA is
    issued.
  * ``W >= 1`` queries per request ride the same kernel: decode is W=1,
    the speculative draft catch-up W=2, and target verify W=k+1.  Rows of
    the ``W*G`` query slab are ordered w-major, so row ``r`` is query
    position ``cnt - W + r // G``.

``interpret=True`` runs the identical kernel through the Pallas
interpreter so CPU CI exercises the real kernel semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, cnt_ref, ring_ref, nblk_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, bs: int, n_b: int, w: int, g: int,
            window: int | None, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j < nblk_ref[b])
    def _block():
        q = q_ref[...].astype(jnp.float32) * scale          # (W*G, hd)
        k = k_ref[...].astype(jnp.float32)                  # (bs, hd)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        cnt = cnt_ref[b]                    # tokens inserted incl. last query
        cap = ring_ref[b]
        last = cnt - 1
        wg = w * g
        idx = j * bs + jax.lax.broadcasted_iota(jnp.int32, (wg, bs), 1)
        stored = last - ((last - idx) % cap)                # abs pos in slot
        qpos = (cnt - w
                + jax.lax.broadcasted_iota(jnp.int32, (wg, bs), 0) // g)
        mask = (idx < cap) & (stored >= 0) & (stored <= qpos)
        if window is not None:
            mask &= (qpos - stored) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                                 # (W*G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # explicit re-mask: a fully-masked tile has s == m_new == NEG_INF,
        # where exp(s - m_new) = 1 would resurrect dead keys
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_b - 1)
    def _epilogue():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention_pallas(q: jax.Array, k_arena: jax.Array,
                           v_arena: jax.Array, block_table: jax.Array,
                           pos: jax.Array, ring_cap: jax.Array, *,
                           window: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """q (B, W, H, hd); arenas (N, bs, KV, hd); block_table (B, MB);
    pos (B,) tokens inserted including the last query (queries sit at
    absolute positions pos-W .. pos-1, and their K/V must already be in the
    arena); ring_cap (B,) per-request ring capacity -> (B, W, H, hd)."""
    b, w, h, hd = q.shape
    _, bs, kv, _ = k_arena.shape
    g = h // kv
    mb = block_table.shape[1]
    scale = hd ** -0.5
    # (B, W, H, hd) -> (B, KV, W*G, hd), rows w-major within a KV group
    qr = q.reshape(b, w, kv, g, hd)
    qr = jnp.moveaxis(qr, 2, 1).reshape(b, kv, w * g, hd)
    cnt = jnp.maximum(pos.astype(jnp.int32), 1)
    ring = jnp.maximum(ring_cap.astype(jnp.int32), 1)
    # logical blocks actually holding keys; trailing blocks are skipped
    nblk = jnp.clip((jnp.minimum(cnt, ring) + bs - 1) // bs, 1, mb)

    def q_index(ib, ih, j, bt, c, r, nb):
        return (ib, ih, 0, 0)

    def kv_index(ib, ih, j, bt, c, r, nb):
        # clamp skipped steps to the last live block: the revisited index
        # elides the DMA, and pl.when skips the compute
        return (bt[ib, jnp.minimum(j, nb[ib] - 1)], 0, ih, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b, kv, mb),
        in_specs=[
            pl.BlockSpec((None, None, w * g, hd), q_index),
            pl.BlockSpec((None, bs, None, hd), kv_index),
            pl.BlockSpec((None, bs, None, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((None, None, w * g, hd), q_index),
        scratch_shapes=[
            pltpu.VMEM((w * g, 1), jnp.float32),    # running max
            pltpu.VMEM((w * g, 1), jnp.float32),    # running denom
            pltpu.VMEM((w * g, hd), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs, n_b=mb, w=w, g=g, window=window,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, w * g, hd), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), cnt, ring, nblk, qr, k_arena, v_arena)
    out = out.reshape(b, kv, w, g, hd)
    return jnp.moveaxis(out, 2, 1).reshape(b, w, h, hd)
