"""Dispatching wrapper for paged attention — the single chokepoint every
serving attention read (decode, speculative catch-up/verify) routes through.

Paths:
  * TPU           -> real pallas_call (compiled flash-decode kernel),
  * forced pallas -> pallas_call(interpret=True) off-TPU (bit-exact kernel
                     semantics for CI parity / the --paged-kernel A/B),
  * otherwise     -> dense gather reference (same math; the pre-kernel
                     serving path).

Selection mirrors ``kernels/qmatmul/ops.fusion``: the scoped
``paged_kernel(enabled)`` context manager pins kernel-vs-gather for
everything traced inside it (a ``contextvars.ContextVar``, so two engines
in one process can hold opposite settings without racing); outside any
scope the backend decides (kernel on TPU, gather elsewhere — interpret-mode
Pallas is pointlessly slow as a CPU default).  ``set_forced_path`` is the
test override that bypasses both.

Like the qmatmul dispatch, head counts come from the operands: under tensor
parallelism (DESIGN.md §11) the call sites sit inside ``shard_map``, so q
carries n_heads/tp query heads and the arena KV/tp KV heads per shard.  The
GQA group ratio (q heads per KV head) is preserved by the all-or-nothing
attention sharding predicate, so kernel and gather paths both work
unchanged on a shard — they just see a narrower head axis.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax

from .paged import paged_attention_pallas
from .ref import paged_attention_ref

_FORCE_PATH: str | None = None  # "pallas" | "ref" | None — tests poke this
_USE_KERNEL: contextvars.ContextVar[bool | None] = contextvars.ContextVar(
    "repro_paged_attention_kernel", default=None)


def set_forced_path(path: str | None) -> None:
    global _FORCE_PATH
    assert path in (None, "pallas", "ref")
    _FORCE_PATH = path


@contextlib.contextmanager
def paged_kernel(enabled: bool | None):
    """Scoped kernel-vs-gather toggle for the paged attention read (True =
    Pallas flash-decode kernel, interpret-mode off TPU; False = dense
    gather reference; None = backend default).  Like ``qops.fusion``, the
    setting applies while tracing inside the ``with`` block and
    nests/unwinds correctly — a jitted engine step keeps whichever path it
    was traced under."""
    token = _USE_KERNEL.set(enabled if enabled is None else bool(enabled))
    try:
        yield
    finally:
        _USE_KERNEL.reset(token)


def kernel_enabled() -> bool:
    """Whether the paged attention read resolves to the Pallas kernel under
    the current scope/backend — read at trace time, e.g. by the verify path
    to decide arena-write ordering (DESIGN.md §10)."""
    return _resolve() == "pallas"


def _resolve() -> str:
    if _FORCE_PATH is not None:
        return _FORCE_PATH
    use = _USE_KERNEL.get()
    if use is None:
        use = jax.default_backend() == "tpu"
    return "pallas" if use else "ref"


def paged_attention(q, k_arena, v_arena, block_table, pos, ring_cap, *,
                    window: int | None = None):
    """q (B, W, H, hd) at absolute positions pos-W..pos-1 (K/V already in
    the arena); arenas (N, bs, KV, hd); block_table (B, MB); pos/ring_cap
    (B,) -> (B, W, H, hd)."""
    if _resolve() == "pallas":
        return paged_attention_pallas(
            q, k_arena, v_arena, block_table, pos, ring_cap, window=window,
            interpret=jax.default_backend() != "tpu")
    return paged_attention_ref(q, k_arena, v_arena, block_table, pos,
                               ring_cap, window=window)
