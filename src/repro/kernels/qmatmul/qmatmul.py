"""Pallas TPU kernel: fused unpack -> dequant -> GEMM with Alg. 3 epilogue.

    Y = (X @ (codes - c_b)) * r
      = (X @ codes) * r - c_b * rowsum(X) * r

Codes arrive packed (8 // bits codes per uint8, packed along the contraction
axis d) and are unpacked *inside* the kernel, so HBM->VMEM traffic for the
weights is b/16 of the bf16 baseline — that is the entire point of weight-only
PTQ at decode time and the term the paper's technique moves (§Roofline).

Blocking: grid (n/bn, c/bc, d/bk), k innermost so the (bn, bc) f32 accumulator
and the (bn, 1) rowsum scratch live in VMEM across the k sweep; the rescale /
z-correction epilogue fires on the last k step.  MXU dims (bn, bk, bc) are
multiples of 128 by construction; the uint8 unpack is a VPU shift/mask on a
(bk//per, bc) tile broadcast to (bk, bc).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BN = 128
DEFAULT_BC = 128
DEFAULT_BK = 512


def _kernel(x_ref, packed_ref, rescale_ref, out_ref, acc_ref, zacc_ref,
            *, bits: int, n_k: int, compute_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        zacc_ref[...] = jnp.zeros_like(zacc_ref)

    x = x_ref[...].astype(compute_dtype)                     # (bn, bk)
    packed = packed_ref[...]                                 # (bk//per, bc) uint8
    per = 8 // bits if bits in (1, 2, 4, 8) else 1
    if per > 1:
        mask = jnp.uint8((1 << bits) - 1)
        parts = [((packed >> jnp.uint8(s * bits)) & mask) for s in range(per)]
        codes = jnp.stack(parts, axis=1).reshape(-1, packed.shape[-1])
    else:
        codes = packed
    codes = codes.astype(compute_dtype)                      # (bk, bc)
    acc_ref[...] += jnp.dot(x, codes, preferred_element_type=jnp.float32)
    zacc_ref[...] += jnp.sum(x.astype(jnp.float32), axis=1, keepdims=True)

    @pl.when(k == n_k - 1)
    def _epilogue():
        c_b = ((1 << bits) - 1) / 2.0
        r = rescale_ref[...].astype(jnp.float32)             # (1, bc)
        out_ref[...] = ((acc_ref[...] - c_b * zacc_ref[...]) * r).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "d", "bn", "bc", "bk",
                                             "interpret", "compute_dtype"))
def quantized_matmul_pallas(x: jax.Array, packed: jax.Array, rescale: jax.Array,
                            *, bits: int, d: int,
                            bn: int = DEFAULT_BN, bc: int = DEFAULT_BC,
                            bk: int = DEFAULT_BK, interpret: bool = True,
                            compute_dtype=jnp.float32) -> jax.Array:
    """x (n, d) f32/bf16, packed (packed_rows, c) uint8, rescale (c,) -> (n, c)."""
    n, _ = x.shape
    c = packed.shape[1]
    per = 8 // bits if bits in (1, 2, 4, 8) else 1
    assert bk % per == 0 and bk % 128 == 0
    d_pad = pl.cdiv(d, bk) * bk
    n_pad = pl.cdiv(n, bn) * bn
    c_pad = pl.cdiv(c, bc) * bc
    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    pp = jnp.zeros((d_pad // per, c_pad), jnp.uint8)
    pp = pp.at[: packed.shape[0], :c].set(packed)
    rp = jnp.zeros((1, c_pad), rescale.dtype).at[0, :c].set(rescale)
    n_k = d_pad // bk
    grid = (n_pad // bn, c_pad // bc, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, n_k=n_k, compute_dtype=compute_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // per, bc), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bc), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bc), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, c_pad), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bn, bc), jnp.float32),   # f32 accumulator
            pltpu.VMEM((bn, 1), jnp.float32),    # rowsum(X) for the z term
        ],
        interpret=interpret,
    )(xp, pp, rp)
    return out[:n, :c]
