"""Pallas TPU kernel: fused unpack -> dequant -> GEMM with Alg. 3 epilogue.

    Y = (X @ (codes - c_b)) * r
      = (X @ codes) * r - c_b * rowsum(X) * r

Codes arrive packed (8 // bits codes per uint8, packed along the contraction
axis d) and are unpacked *inside* the kernel, so HBM->VMEM traffic for the
weights is b/16 of the bf16 baseline — that is the entire point of weight-only
PTQ at decode time and the term the paper's technique moves (§Roofline).

Blocking: grid (n/bn, c/bc, d/bk), k innermost so the (bn, bc) f32 accumulator
and the (bn, 1) rowsum scratch live in VMEM across the k sweep; the rescale /
z-correction epilogue fires on the last k step.  MXU dims (bn, bk, bc) are
multiples of 128 by construction; the uint8 unpack is a VPU shift/mask on a
(bk//per, bc) tile broadcast to (bk, bc).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.hadamard import _split_dim, hadamard_matrix, largest_pow2_leq

DEFAULT_BN = 128
DEFAULT_BC = 128
DEFAULT_BK = 512


def _unpack_tile(packed: jax.Array, bits: int) -> jax.Array:
    """(bk//per, bc) uint8 -> (bk, bc) uint8 via VPU shift/mask."""
    per = 8 // bits if bits in (1, 2, 4, 8) else 1
    if per == 1:
        return packed
    mask = jnp.uint8((1 << bits) - 1)
    parts = [((packed >> jnp.uint8(s * bits)) & mask) for s in range(per)]
    return jnp.stack(parts, axis=1).reshape(-1, packed.shape[-1])


def _kernel(x_ref, packed_ref, rescale_ref, out_ref, acc_ref, zacc_ref,
            *, bits: int, n_k: int, compute_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        zacc_ref[...] = jnp.zeros_like(zacc_ref)

    x = x_ref[...].astype(compute_dtype)                     # (bn, bk)
    codes = _unpack_tile(packed_ref[...], bits).astype(compute_dtype)  # (bk, bc)
    acc_ref[...] += jnp.dot(x, codes, preferred_element_type=jnp.float32)
    zacc_ref[...] += jnp.sum(x.astype(jnp.float32), axis=1, keepdims=True)

    @pl.when(k == n_k - 1)
    def _epilogue():
        c_b = ((1 << bits) - 1) / 2.0
        r = rescale_ref[...].astype(jnp.float32)             # (1, bc)
        out_ref[...] = ((acc_ref[...] - c_b * zacc_ref[...]) * r).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "d", "bn", "bc", "bk",
                                             "interpret", "compute_dtype"))
def quantized_matmul_pallas(x: jax.Array, packed: jax.Array, rescale: jax.Array,
                            *, bits: int, d: int,
                            bn: int = DEFAULT_BN, bc: int = DEFAULT_BC,
                            bk: int = DEFAULT_BK, interpret: bool = True,
                            compute_dtype=jnp.float32) -> jax.Array:
    """x (n, d) f32/bf16, packed (packed_rows, c) uint8, rescale (c,) -> (n, c)."""
    n, _ = x.shape
    c = packed.shape[1]
    per = 8 // bits if bits in (1, 2, 4, 8) else 1
    assert bk % per == 0 and bk % 128 == 0
    d_pad = pl.cdiv(d, bk) * bk
    n_pad = pl.cdiv(n, bn) * bn
    c_pad = pl.cdiv(c, bc) * bc
    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    pp = jnp.zeros((d_pad // per, c_pad), jnp.uint8)
    pp = pp.at[: packed.shape[0], :c].set(packed)
    rp = jnp.zeros((1, c_pad), rescale.dtype).at[0, :c].set(rescale)
    n_k = d_pad // bk
    grid = (n_pad // bn, c_pad // bc, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, n_k=n_k, compute_dtype=compute_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // per, bc), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bc), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bc), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, c_pad), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bn, bc), jnp.float32),   # f32 accumulator
            pltpu.VMEM((bn, 1), jnp.float32),    # rowsum(X) for the z term
        ],
        interpret=interpret,
    )(xp, pp, rp)
    return out[:n, :c]


# ===================================================== fused RHT + qmatmul


def _rht_rows(x, signs, h1, h2, *, d1: int, d2: int):
    """H_{d1*d2} (D x) for a VMEM row tile x (bn, d1*d2); signs (1, d1*d2).

    Same Kronecker two-matmul factorization as kernels/hadamard, inlined so
    the rotated tile never leaves VMEM before the quantized GEMM consumes it.
    """
    x = x * signs
    bn = x.shape[0]
    xr = x.reshape(bn * d1, d2)
    xr = jnp.dot(xr, h2, preferred_element_type=jnp.float32)           # H_{d2}
    xr = xr.reshape(bn, d1, d2).swapaxes(1, 2).reshape(bn * d2, d1)
    xr = jnp.dot(xr, h1, preferred_element_type=jnp.float32)           # H_{d1}
    return xr.reshape(bn, d2, d1).swapaxes(1, 2).reshape(bn, d1 * d2)


def _fused_kernel(x_ref, signs1_ref, signs2_ref, h1_ref, h2_ref, packed_ref,
                  rescale_ref, out_ref, xrot_ref, acc_ref, zacc_ref,
                  *, bits: int, n_k: int, bk: int, d: int, d_hat: int,
                  d1: int, d2: int, overlapped: bool, compute_dtype):
    j, k = pl.program_id(1), pl.program_id(2)

    # Rotate once per row block (first (j, k) visit); the (bn, d_pad) result
    # stays resident in VMEM scratch for the whole (j, k) sweep — rotated
    # activations never touch HBM (Alg. 3 fused with Alg. 5).
    @pl.when((j == 0) & (k == 0))
    def _rotate():
        xf = x_ref[...].astype(jnp.float32)                  # (bn, d_pad)
        blk1 = _rht_rows(xf[:, :d_hat], signs1_ref[...], h1_ref[...],
                         h2_ref[...], d1=d1, d2=d2)
        row = (jnp.concatenate([blk1, xf[:, d_hat:]], axis=1)
               if xf.shape[1] > d_hat else blk1)
        if overlapped:                                       # Alg. 5, d not pow2
            lo = d - d_hat
            blk2 = _rht_rows(row[:, lo:d], signs2_ref[...], h1_ref[...],
                             h2_ref[...], d1=d1, d2=d2)
            row = jnp.concatenate([row[:, :lo], blk2, row[:, d:]], axis=1)
        xrot_ref[...] = row

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        zacc_ref[...] = jnp.zeros_like(zacc_ref)

    x = xrot_ref[:, pl.ds(k * bk, bk)].astype(compute_dtype)            # (bn, bk)
    codes = _unpack_tile(packed_ref[...], bits).astype(compute_dtype)   # (bk, bc)
    acc_ref[...] += jnp.dot(x, codes, preferred_element_type=jnp.float32)
    zacc_ref[...] += jnp.sum(x.astype(jnp.float32), axis=1, keepdims=True)

    @pl.when(k == n_k - 1)
    def _epilogue():
        c_b = ((1 << bits) - 1) / 2.0
        r = rescale_ref[...].astype(jnp.float32)             # (1, bc)
        out_ref[...] = ((acc_ref[...] - c_b * zacc_ref[...]) * r).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "d", "bn", "bc", "bk",
                                             "interpret", "compute_dtype"))
def rht_quantized_matmul_pallas(x: jax.Array, packed: jax.Array,
                                rescale: jax.Array, signs1: jax.Array,
                                signs2: jax.Array | None, *, bits: int, d: int,
                                bn: int = DEFAULT_BN, bc: int = DEFAULT_BC,
                                bk: int = DEFAULT_BK, interpret: bool = True,
                                compute_dtype=jnp.float32) -> jax.Array:
    """Y = practical_rht(x) @ (r * (codes - c_b)) without the HBM round trip.

    x (n, d) f32/bf16, packed (packed_rows, c) uint8, rescale (c,),
    signs1/signs2 (d_hat,) Rademacher (signs2 None iff d is a power of 2).
    """
    n, _ = x.shape
    c = packed.shape[1]
    d_hat = largest_pow2_leq(d)
    d1, d2 = _split_dim(d_hat)
    overlapped = d_hat != d
    if overlapped and signs2 is None:
        raise ValueError("signs2 required when d is not a power of 2")
    if signs2 is None:
        signs2 = jnp.zeros((d_hat,), jnp.float32)            # dead input
    per = 8 // bits if bits in (1, 2, 4, 8) else 1
    assert bk % per == 0 and bk % 128 == 0
    d_pad = pl.cdiv(d, bk) * bk
    n_pad = pl.cdiv(n, bn) * bn
    c_pad = pl.cdiv(c, bc) * bc
    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x)
    pp = jnp.zeros((d_pad // per, c_pad), jnp.uint8)
    pp = pp.at[: packed.shape[0], :c].set(packed)
    rp = jnp.zeros((1, c_pad), rescale.dtype).at[0, :c].set(rescale)
    h1 = hadamard_matrix(d1)
    h2 = hadamard_matrix(d2)
    n_k = d_pad // bk
    grid = (n_pad // bn, c_pad // bc, n_k)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, bits=bits, n_k=n_k, bk=bk, d=d,
                          d_hat=d_hat, d1=d1, d2=d2, overlapped=overlapped,
                          compute_dtype=compute_dtype),
        grid=grid,
        in_specs=[
            # same block for every (j, k) -> fetched from HBM once per row block
            pl.BlockSpec((bn, d_pad), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, d_hat), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, d_hat), lambda i, j, k: (0, 0)),
            pl.BlockSpec((d1, d1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((d2, d2), lambda i, j, k: (0, 0)),
            pl.BlockSpec((bk // per, bc), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bc), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bc), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, c_pad), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bn, d_pad), jnp.float32),  # rotated activations
            pltpu.VMEM((bn, bc), jnp.float32),     # f32 accumulator
            pltpu.VMEM((bn, 1), jnp.float32),      # rowsum for the z term
        ],
        interpret=interpret,
    )(xp, signs1.reshape(1, d_hat).astype(jnp.float32),
      signs2.reshape(1, d_hat).astype(jnp.float32), h1, h2, pp, rp)
    return out[:n, :c]
