"""Dispatching wrapper for the fused dequant GEMM.

Paths:
  * TPU          -> real pallas_call (compiled kernel),
  * tests        -> pallas_call(interpret=True) (bit-exact kernel semantics),
  * CPU / dryrun -> pure-jnp reference (same math; interpret-mode would be
                    pointlessly slow inside a 512-way SPMD dry-run compile).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .qmatmul import quantized_matmul_pallas
from .ref import quantized_matmul_ref

_FORCE_PATH: str | None = None  # "pallas" | "ref" | None (auto) — tests poke this


def set_forced_path(path: str | None) -> None:
    global _FORCE_PATH
    assert path in (None, "pallas", "ref")
    _FORCE_PATH = path


def quantized_matmul(x: jax.Array, packed: jax.Array, rescale: jax.Array,
                     *, bits: int, d: int) -> jax.Array:
    """Estimate X @ (r * (codes - c_b)) for X (..., d) -> (..., c)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    path = _FORCE_PATH
    if path is None:
        path = "pallas" if jax.default_backend() == "tpu" else "ref"
    if path == "pallas":
        y = quantized_matmul_pallas(x2, packed, rescale, bits=bits, d=d,
                                    interpret=jax.default_backend() != "tpu")
    else:
        y = quantized_matmul_ref(x2, packed, rescale, bits=bits, d=d)
    return y.reshape(*lead, y.shape[-1])
