"""Dispatching wrapper for the fused dequant GEMM — the single chokepoint the
decode hot path (QuantizedLinear / QuantizedGrouped -> serve/decode) routes
through.

Paths:
  * TPU          -> real pallas_call (compiled kernel),
  * tests        -> pallas_call(interpret=True) (bit-exact kernel semantics),
  * CPU / dryrun -> pure-jnp reference (same math; interpret-mode would be
                    pointlessly slow inside a 512-way SPMD dry-run compile).

Fusion: by default the practical RHT (Alg. 5) is applied *inside* the qmatmul
kernel (``rht_quantized_matmul``) so rotated activations never round-trip
through HBM between the Hadamard stage and the dequant GEMM.  The scoped
``fusion(enabled)`` context manager selects the legacy two-kernel composition
for A/B benchmarking (benchmarks/serve_bench.py reports both); it is backed by
a ``contextvars.ContextVar`` so a serving engine and a benchmark running in
the same process cannot race each other's toggles the way a mutable module
global could.

Shapes are taken from the operands, never from a config: under tensor
parallelism (DESIGN.md §11) these entry points run *inside* ``shard_map``
blocks, where the packed codes / rescale / w_out carry per-shard column
counts (c/tp of the full layer).  Every column's estimator (Alg. 3) depends
only on that column's codes and side info plus the full rotated activation,
so a shard computes exactly the columns it owns and the dispatch needs no
TP awareness at all.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
import jax.numpy as jnp

from .qmatmul import quantized_matmul_pallas, rht_quantized_matmul_pallas
from .ref import quantized_matmul_ref, rht_quantized_matmul_ref

_FORCE_PATH: str | None = None  # "pallas" | "ref" | None (auto) — tests poke this
_FUSE_RHT: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_qmatmul_fuse_rht", default=True)


def set_forced_path(path: str | None) -> None:
    global _FORCE_PATH
    assert path in (None, "pallas", "ref")
    _FORCE_PATH = path


@contextlib.contextmanager
def fusion(enabled: bool):
    """Scoped RHT+GEMM fusion toggle (True = fused single-dispatch kernel,
    the default; False = legacy two-kernel composition where rotated
    activations round-trip through HBM, kept for A/B measurement).

    The setting only applies while tracing/executing inside the ``with``
    block, and nests/unwinds correctly — concurrent contexts (engine vs
    benchmark) each see their own value, so two engines in one process can
    hold opposite settings without racing.  This is the only supported
    toggle; the old process-wide ``set_fused`` mutator has been removed.
    """
    token = _FUSE_RHT.set(bool(enabled))
    try:
        yield
    finally:
        _FUSE_RHT.reset(token)


def fused_enabled() -> bool:
    """Current fusion setting (the innermost enclosing ``fusion`` scope, or
    the fused default when none is active)."""
    return _FUSE_RHT.get()


def _resolve_path() -> str:
    path = _FORCE_PATH
    if path is None:
        path = "pallas" if jax.default_backend() == "tpu" else "ref"
    return path


def quantized_matmul(x: jax.Array, packed: jax.Array, rescale: jax.Array,
                     *, bits: int, d: int) -> jax.Array:
    """Estimate X @ (r * (codes - c_b)) for X (..., d) -> (..., c)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if _resolve_path() == "pallas":
        y = quantized_matmul_pallas(x2, packed, rescale, bits=bits, d=d,
                                    interpret=jax.default_backend() != "tpu")
    else:
        y = quantized_matmul_ref(x2, packed, rescale, bits=bits, d=d)
    return y.reshape(*lead, y.shape[-1])


def rht_quantized_matmul(x: jax.Array, packed: jax.Array, rescale: jax.Array,
                         signs1: jax.Array, signs2: jax.Array | None,
                         *, bits: int, d: int) -> jax.Array:
    """Estimate practical_rht(X) @ (r * (codes - c_b)) for X (..., d).

    The decode hot path: with fusion on, the RHT's Kronecker matmuls happen in
    VMEM inside the qmatmul kernel; with fusion off, rotated activations are
    materialized between two kernels (the pre-fusion behavior).
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if not _FUSE_RHT.get():
        from repro.kernels.hadamard import ops as hops  # late: avoid cycle
        xr = hops.practical_rht(x2.astype(jnp.float32), signs1, signs2)
        return quantized_matmul(xr, packed, rescale, bits=bits, d=d
                                ).reshape(*lead, -1)
    if _resolve_path() == "pallas":
        y = rht_quantized_matmul_pallas(
            x2, packed, rescale, signs1, signs2, bits=bits, d=d,
            interpret=jax.default_backend() != "tpu")
    else:
        y = rht_quantized_matmul_ref(x2, packed, rescale, signs1, signs2,
                                     bits=bits, d=d)
    return y.reshape(*lead, y.shape[-1])


def grouped_rht_quantized_matmul(x: jax.Array, packed: jax.Array,
                                 rescale: jax.Array, signs1: jax.Array,
                                 signs2: jax.Array | None,
                                 *, bits: int, d: int) -> jax.Array:
    """Per-expert fused estimate: x (E, C, d), packed (E, pr, c),
    rescale (E, c) -> (E, C, c).  Signs are shared across experts (same input
    space), so the whole MoE FFN is one vmap over the fused kernel — packed
    codes stay packed; no dense (E, d, c) dequant buffer exists at any point.
    """
    return jax.vmap(
        lambda xe, pe, re: rht_quantized_matmul(
            xe, pe, re, signs1, signs2, bits=bits, d=d)
    )(x, packed, rescale)
