"""Pure-jnp oracles for the (RHT-)fused dequant GEMM (paper Alg. 3 / Alg. 5)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hadamard, packing


def quantized_matmul_ref(x: jax.Array, packed: jax.Array, rescale: jax.Array,
                         *, bits: int, d: int) -> jax.Array:
    """Y = (X @ (codes - c_b)) * r  for X (n, d), packed codes, r (c,)."""
    codes = packing.unpack_codes(packed, bits, d).astype(jnp.float32)
    c_b = ((1 << bits) - 1) / 2.0
    x = x.astype(jnp.float32)
    y = x @ codes - c_b * jnp.sum(x, axis=-1, keepdims=True)
    return y * rescale[None, :].astype(jnp.float32)


def rht_quantized_matmul_ref(x: jax.Array, packed: jax.Array,
                             rescale: jax.Array, signs1: jax.Array,
                             signs2: jax.Array | None, *, bits: int,
                             d: int) -> jax.Array:
    """Unfused composition the fused kernel must match: Alg. 5 then Alg. 3."""
    xr = hadamard.practical_rht(x.astype(jnp.float32), signs1, signs2, axis=-1)
    return quantized_matmul_ref(xr, packed, rescale, bits=bits, d=d)
