"""Oracle: the (separately validated) chunked-jnp flash attention."""
from __future__ import annotations

import jax

from repro.models.attention import flash_attention


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal=True,
                  window=None) -> jax.Array:
    return flash_attention(q, k, v, causal=causal, window=window,
                           q_chunk=128, k_chunk=128)
