"""Pallas TPU kernel: fused flash-attention forward (online softmax).

This is the "next lever" identified by §Perf cells A/B: the pure-jnp chunked
attention pays HBM round-trips for every score/exp/select tensor (measured at
~25-40 % of train-step bytes); fusing the whole (bq, bk) tile pipeline —
scores -> mask -> online softmax -> PV accumulate — into one kernel keeps all
S^2-shaped intermediates in VMEM.  The MXU sees two matmuls per tile; the
accumulator (bq, hd) and the running (m, l) stats live in VMEM scratch across
the KV sweep.

Layout: caller flattens heads into the leading grid dim; GQA is handled by an
index map that routes query-head blocks to their shared KV head (no KV
expansion in HBM).  Causal/window masking is positional, computed in-kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, n_k: int, sk: int, causal: bool,
            window: int | None, scale: float):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    iq = pl.program_id(2)
    q = q_ref[0].astype(jnp.float32) * scale              # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                      # (bk, hd)
    v = v_ref[0].astype(jnp.float32)                      # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < sk                                      # key padding
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[...]                                   # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                                # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                       # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q (B,S,H,hd), k/v (B,S,KV,hd), H = KV*G -> (B,S,H,hd)."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    bq = min(bq, max(8, sq))
    bk = min(bk, max(8, sk))
    nq, nk = pl.cdiv(sq, bq), pl.cdiv(sk, bk)
    sq_p, sk_p = nq * bq, nk * bk
    # flatten (B, H) into the leading axis; keys stay at (B, KV)
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * kv, sk, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * kv, sk, hd)
    if sq_p != sq:
        qf = jnp.pad(qf, ((0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        kf = jnp.pad(kf, ((0, 0), (0, sk_p - sk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, sk_p - sk), (0, 0)))

    def q_index(ib, ih, iq, ik):
        return (ib * h + ih, iq, 0)

    def kv_index(ib, ih, iq, ik):
        return (ib * kv + ih // g, ik, 0)                  # GQA head routing

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, n_k=nk, sk=sk,
                          causal=causal, window=window, scale=scale),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_index),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), q_index),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :sq].reshape(b, h, sq, hd)
    return jnp.moveaxis(out, 1, 2)
