"""Dispatching wrapper for fused flash attention."""
from __future__ import annotations

import jax

from .flash import flash_attention_pallas
from .ref import attention_ref

_FORCE_PATH: str | None = None


def set_forced_path(path: str | None) -> None:
    global _FORCE_PATH
    assert path in (None, "pallas", "ref")
    _FORCE_PATH = path


def attention(q, k, v, *, causal: bool = True, window: int | None = None):
    path = _FORCE_PATH
    if path is None:
        path = "pallas" if jax.default_backend() == "tpu" else "ref"
    if path == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=jax.default_backend() != "tpu")
    return attention_ref(q, k, v, causal=causal, window=window)
