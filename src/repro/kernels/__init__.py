"""Pallas TPU kernels for RaanA's compute hot-spots.

Five kernels — three are TPU-native adaptations of stages the paper runs on
CPU/GPU (DESIGN.md §3), the other two (flash_attention, paged_attention)
are the beyond-paper inference-efficiency levers:

  * ``hadamard``        — RHT as two MXU matmuls per VMEM-resident tile
                          (Kronecker-factorized FWHT; Hadacore's tensor-core
                          idea re-thought for the 128x128 systolic array).
  * ``qmatmul``         — fused unpack -> dequant -> GEMM with the Alg. 3
                          rescale/z epilogue; codes cross HBM packed.
  * ``rabitq_quant``    — per-column candidate-sweep code search + LS rescale.
  * ``flash_attention`` — fused online-softmax forward (EXPERIMENTS.md §Perf).
  * ``paged_attention`` — flash-decoding over the serving engine's block
                          arena, block table chased in-kernel (DESIGN.md §10).

Every ``ops.py`` wrapper dispatches: real ``pallas_call`` on TPU,
``interpret=True`` execution in tests, and a pure-jnp reference path for
large CPU/dry-run work where interpret-mode would be needlessly slow.
"""
import jax


def default_interpret() -> bool:
    """True when no TPU is present (CPU container -> interpret mode)."""
    return jax.default_backend() != "tpu"
