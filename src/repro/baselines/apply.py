"""Apply a baseline PTQ method to a whole model (drop-in reconstructed
weights), mirroring core.pipeline.quantize_model's layer selection so
average-bits accounting is comparable."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import _get, _set, _walk_layer
from repro.models.common import LinearCtx
from repro.models import transformer as tf
from repro.models.config import ModelConfig

from .quant_baselines import awq_quantize, gptq_quantize, rtn_quantize


def collect_hessians(cfg: ModelConfig, params: dict, batches) -> dict:
    ctx = LinearCtx(collect_hessian=True, collect=True)
    for b in batches:
        tf.loss_fn(cfg, params, b, ctx=ctx, scan=False)
    return ({k: np.asarray(v) for k, v in ctx.hessians.items()},
            {k: np.asarray(jnp.sqrt(t["x_col_sq"])) for k, t in
             ctx.taps.items()})


def apply_baseline(cfg: ModelConfig, params: dict, method: str, bits: int,
                   hessians: dict | None = None,
                   x_col_norms: dict | None = None, group: int = 128):
    """Returns (params with reconstructed weights, achieved avg bits, time)."""
    t0 = time.time()
    p_period = cfg.scan_period
    out = dict(params)
    out["layers"] = []
    total_bits = 0
    total_m = 0
    for jpos, stack in enumerate(params["layers"]):
        n_j = (len(stack) if isinstance(stack, list)
               else jax.tree.leaves(stack)[0].shape[0])
        lst = []
        for idx in range(n_j):
            i = idx * p_period + jpos
            lp = (stack[idx] if isinstance(stack, list)
                  else jax.tree.map(lambda a: a[idx], stack))
            lp = jax.tree.map(lambda a: a, lp)
            for path, kind in _walk_layer(lp):
                if kind != "linear":
                    continue          # baselines cover 2-D weights only
                name = f"L{i}." + ".".join(path)
                w = np.asarray(_get(lp, path), np.float32)
                if method == "rtn":
                    wq, ovh = rtn_quantize(w, bits, group)
                elif method == "gptq":
                    h = None if hessians is None else hessians.get(name)
                    if h is None:
                        h = np.eye(w.shape[0])
                    wq, ovh = gptq_quantize(w, h, bits, group)
                elif method == "awq":
                    n = None if x_col_norms is None else x_col_norms.get(name)
                    if n is None:
                        n = np.ones(w.shape[0])
                    wq, ovh, _ = awq_quantize(w, n, bits, group)
                else:
                    raise ValueError(method)
                _set(lp, path, jnp.asarray(wq))
                total_bits += bits * w.size + ovh
                total_m += w.size
            lst.append(lp)
        out["layers"].append(lst)
    avg_bits = total_bits / max(total_m, 1)
    return out, avg_bits, time.time() - t0
