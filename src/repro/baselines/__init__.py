from .quant_baselines import awq_quantize, gptq_quantize, rtn_quantize  # noqa: F401
