"""Baseline PTQ methods the paper compares against (Table 1): RTN (grouped
round-to-nearest), GPTQ (layer-Hessian OBQ, arXiv:2210.17323), and an
AWQ-style activation-aware scaling (arXiv:2306.00978).

All take W (d_in, d_out) and return a reconstructed fp weight of the same
shape (drop-in evaluation, like core.qlinear.reconstruct_weight), plus the
side-info bit cost so average-bits accounting matches RaanA's.

Host-side numpy: these run once per layer at quantization time.
"""
from __future__ import annotations

import numpy as np


def _uniform_grid(w: np.ndarray, bits: int, axis: int = 0, group: int = 0):
    """Asymmetric min/max uniform quantization along ``axis`` (optionally in
    groups of ``group`` input dims). Returns reconstructed array."""
    levels = (1 << bits) - 1
    if group and w.shape[0] > group:
        d = w.shape[0]
        pad = (-d) % group
        wp = np.concatenate([w, np.zeros((pad, *w.shape[1:]), w.dtype)], 0)
        wg = wp.reshape(-1, group, *w.shape[1:])
        lo = wg.min(axis=1, keepdims=True)
        hi = wg.max(axis=1, keepdims=True)
        scale = np.maximum(hi - lo, 1e-12) / levels
        q = np.clip(np.round((wg - lo) / scale), 0, levels)
        out = (q * scale + lo).reshape(-1, *w.shape[1:])[:d]
        return out
    lo = w.min(axis=axis, keepdims=True)
    hi = w.max(axis=axis, keepdims=True)
    scale = np.maximum(hi - lo, 1e-12) / levels
    q = np.clip(np.round((w - lo) / scale), 0, levels)
    return q * scale + lo


def rtn_quantize(w: np.ndarray, bits: int, group: int = 128):
    """Grouped round-to-nearest.  Side info: (scale+zero) fp16 per group ->
    2*16/group extra bits per weight."""
    w = np.asarray(w, np.float32)
    out = _uniform_grid(w, bits, axis=0, group=group)
    overhead_bits = int(2 * 16 * np.ceil(w.shape[0] / group) * w.shape[1])
    return out.astype(np.float32), overhead_bits


def gptq_quantize(w: np.ndarray, hessian: np.ndarray, bits: int,
                  group: int = 128, percdamp: float = 0.01):
    """GPTQ: quantize input dims in order, propagating error through the
    Cholesky factor of the damped inverse Hessian H = X^T X (d_in, d_in)."""
    w = np.array(w, np.float32, copy=True)           # (d, c)
    d, c = w.shape
    h = np.array(hessian, np.float64, copy=True)
    dead = np.diag(h) == 0
    h[dead, dead] = 1.0
    w[dead, :] = 0.0
    damp = percdamp * np.mean(np.diag(h))
    h[np.arange(d), np.arange(d)] += damp
    hinv = np.linalg.inv(h)
    # upper U with H^-1 = U^T U (as in the reference implementation):
    # chol lower L gives H^-1 = L L^T, so U = L^T.
    u = np.linalg.cholesky(hinv).T
    levels = (1 << bits) - 1
    out = np.zeros_like(w)
    lo = hi = scale = zero = None
    for i in range(d):
        if group and i % group == 0:
            blk = w[i: i + group]
            lo = blk.min(axis=0)
            hi = blk.max(axis=0)
            scale = np.maximum(hi - lo, 1e-12) / levels
            zero = lo
        q = np.clip(np.round((w[i] - zero) / scale), 0, levels)
        wq = q * scale + zero
        out[i] = wq
        err = (w[i] - wq) / u[i, i]
        if i + 1 < d:
            w[i + 1:] -= np.outer(u[i, i + 1:], err)
    overhead_bits = int(2 * 16 * np.ceil(d / group) * c)
    return out.astype(np.float32), overhead_bits


def awq_quantize(w: np.ndarray, x_col_norms: np.ndarray, bits: int,
                 group: int = 128, alphas=(0.0, 0.25, 0.5, 0.75, 1.0)):
    """AWQ-style: scale salient input dims up before RTN, fold the inverse
    scale back exactly.  Grid-search alpha minimizing ||diag(n)(W - W_hat)||_F
    (column-norm proxy for the activation-weighted error)."""
    w = np.asarray(w, np.float32)
    n = np.asarray(x_col_norms, np.float64)
    n = n / max(n.mean(), 1e-12)
    best, best_err, best_alpha = None, np.inf, 0.0
    for a in alphas:
        s = np.maximum(n ** a, 1e-6)[:, None]
        wq = _uniform_grid(w * s, bits, axis=0, group=group) / s
        err = float(np.linalg.norm((w - wq) * n[:, None]))
        if err < best_err:
            best, best_err, best_alpha = wq, err, a
    overhead_bits = int(2 * 16 * np.ceil(w.shape[0] / group) * w.shape[1]
                        + 16 * w.shape[0])
    return best.astype(np.float32), overhead_bits, best_alpha
