"""AllocateBits (paper §4, Alg. 4): optimal layer-wise bit allocation.

Minimize  sum_k alpha_k * 2^{-b_k}   s.t.   sum_k b_k * m_k <= R,  b_k in B,

solved exactly by dynamic programming over the budget axis after dividing all
m_k and R by g = gcd(m_1..m_L, R) — the paper's divide-by-GCD trick, which is
what makes the DP table small enough (R/g ~ 1e5) to solve in seconds on host.

When the slot count still exceeds ``_MAX_SLOTS`` the budget unit is coarsened
and g no longer divides the m_k, so the per-(layer, bits) slot costs are
rounded.  Round-to-nearest (Alg. 4's floor(m b / g + 1/2)) can *under*-count
real bits, so the reconstructed allocation is verified against the true
budget and, if it overruns, the DP is re-solved with ceiling costs — which
over-count and therefore guarantee sum b_k m_k <= g * n_slots <= R.  The
all-minimum-bits assignment is feasible by the entry precondition, so the
repair always terminates with a true-budget-feasible result.

Everything here is host-side numpy: allocation happens once per model, before
quantization, and its output (a python list of ints) is static metadata.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

import numpy as np

__all__ = ["AllocationResult", "allocate_bits", "allocate_for_avg_bits",
           "brute_force_allocate"]

# Above this many DP budget slots we coarsen the budget unit and accept a
# sub-1-slot rounding of R (documented safeguard; never triggers when layer
# sizes share a large gcd, which the paper notes is the common LLM case).
_MAX_SLOTS = 4_000_000


@dataclass(frozen=True)
class AllocationResult:
    bits: list[int]          # chosen b_k per layer
    total_bits: int          # sum b_k * m_k actually used
    budget: int              # requested R
    objective: float         # sum alpha_k 2^{-b_k}
    gcd: int                 # the g actually used by the DP
    n_slots: int             # R // g
    total_params: int = 0    # sum m_k (0 only if the caller omitted it)

    @property
    def avg_bits(self) -> float:
        return self.total_bits / max(1, self.total_params)


def _gcd_many(vals: Sequence[int]) -> int:
    g = 0
    for v in vals:
        g = math.gcd(g, int(v))
    return max(g, 1)


def allocate_for_avg_bits(alphas: Sequence[float], m: Sequence[int],
                          avg_bits: float, bit_choices: Sequence[int]
                          ) -> AllocationResult:
    """Convenience wrapper: budget R = avg_bits * total params (floored)."""
    r = int(math.floor(avg_bits * sum(int(x) for x in m)))
    return allocate_bits(alphas, m, r, bit_choices)


def _dp_solve(err: np.ndarray, costs: np.ndarray, bits: list[int],
              n_slots: int):
    """DP over the slot axis.  Returns (picked bits per layer, objective) or
    None when no assignment fits in ``n_slots`` slots under ``costs``."""
    num_layers = costs.shape[0]
    inf = np.inf
    f = np.full(n_slots + 1, inf)
    f[0] = 0.0
    choice = np.zeros((num_layers, n_slots + 1), dtype=np.int8)

    for k in range(num_layers):
        newf = np.full(n_slots + 1, inf)
        ch = np.zeros(n_slots + 1, dtype=np.int8)
        for j in range(len(bits)):
            ckj = int(costs[k, j])
            if ckj > n_slots:
                continue
            cand = np.full(n_slots + 1, inf)
            cand[ckj:] = f[: n_slots + 1 - ckj] + err[k, j]
            better = cand < newf
            newf = np.where(better, cand, newf)
            ch = np.where(better, np.int8(j), ch)
        f = newf
        choice[k] = ch

    if not np.isfinite(f).any():
        return None
    r = int(np.argmin(f))
    objective = float(f[r])
    picked = []
    for k in range(num_layers - 1, -1, -1):
        j = int(choice[k, r])
        picked.append(bits[j])
        r -= int(costs[k, j])
    picked.reverse()
    return picked, objective


def allocate_bits(alphas: Sequence[float], m: Sequence[int], budget: int,
                  bit_choices: Sequence[int]) -> AllocationResult:
    """Exact DP solve of the bit-allocation integer program (Alg. 4).

    The returned allocation always satisfies ``total_bits <= budget``, even
    on the coarsened-g path where the DP's slot costs are rounded."""
    alphas = np.asarray(alphas, dtype=np.float64)
    m = np.asarray(m, dtype=np.int64)
    bits = sorted(int(b) for b in set(bit_choices))
    num_layers = len(m)
    if num_layers == 0:
        raise ValueError("no layers to allocate")
    if alphas.shape[0] != num_layers:
        raise ValueError("alphas and m must have the same length")
    if budget < bits[0] * int(m.sum()):
        raise ValueError(
            f"budget {budget} below the minimum {bits[0] * int(m.sum())} "
            f"(every layer at {bits[0]} bits)")

    g = _gcd_many(list(m) + [budget])
    n_slots = budget // g
    if n_slots > _MAX_SLOTS:                       # coarsen (safeguard)
        factor = int(math.ceil(n_slots / _MAX_SLOTS))
        g *= factor
        n_slots = budget // g

    err = (alphas[:, None] * np.exp2(-np.asarray(bits, dtype=np.float64))[None, :])
    bcol = np.asarray(bits, dtype=np.int64)[None, :]
    # round-to-nearest slot count, as in Alg. 4:  floor(m_k b / g + 1/2)
    costs = (m[:, None] * bcol + g // 2) // g

    solved = _dp_solve(err, costs, bits, n_slots)
    picked, objective = solved if solved is not None else (None, None)

    def total(bs):
        return int(np.sum(np.asarray(bs, dtype=np.int64) * m))

    if picked is None or total(picked) > budget:
        # Nearest-rounding under-counted (only possible when g does not
        # divide the m_k, i.e. the coarsened path): re-solve with ceiling
        # costs, which over-count and so can never exceed the true budget.
        costs = -((-m[:, None] * bcol) // g)
        solved = _dp_solve(err, costs, bits, n_slots)
        if solved is not None:
            picked, objective = solved
        else:
            # Ceiling costs over-shrank the feasible set; the all-minimum
            # assignment fits the true budget by the precondition above.
            picked = [bits[0]] * num_layers
            objective = float(np.sum(err[:, 0]))
    total_bits = total(picked)
    assert total_bits <= budget, "allocation repair failed to fit budget"
    return AllocationResult(bits=picked, total_bits=total_bits, budget=budget,
                            objective=objective, gcd=g, n_slots=n_slots,
                            total_params=int(m.sum()))


def brute_force_allocate(alphas, m, budget, bit_choices) -> AllocationResult:
    """Exponential exhaustive reference for tests (small L only)."""
    import itertools
    alphas = list(map(float, alphas))
    m = list(map(int, m))
    best, best_obj = None, np.inf
    for combo in itertools.product(sorted(set(bit_choices)), repeat=len(m)):
        if sum(b * mk for b, mk in zip(combo, m)) > budget:
            continue
        obj = sum(a * 2.0 ** (-b) for a, b in zip(alphas, combo))
        if obj < best_obj:
            best, best_obj = combo, obj
    if best is None:
        raise ValueError("infeasible")
    return AllocationResult(bits=list(best),
                            total_bits=sum(b * mk for b, mk in zip(best, m)),
                            budget=budget, objective=best_obj, gcd=1,
                            n_slots=budget, total_params=int(sum(m)))
