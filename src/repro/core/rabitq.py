"""Extended (multi-bit) RaBitQ quantization, sans rotation (paper §5, App. A.2).

The rotation is supplied externally by the practical RHT (``core.hadamard``);
this module quantizes already-rotated column vectors to b-bit unsigned codes
with a per-column rescale factor so that inner products are estimated as

    <x, w>  ~=  r * <x, (codes - c_b * 1)>,      c_b = (2^b - 1) / 2.

TPU-native adaptation (DESIGN.md §3): the reference RaBitQ performs a per-
vector iterative grid-step search on CPU.  We instead sweep a fixed geometric
grid of ``n_candidates`` grid steps for *all* columns in parallel (pure
reductions over the d axis -> VPU friendly, vmap/vmem friendly), pick the
argmin-reconstruction-error step per column, and finish with the closed-form
least-squares rescale r = <w,v>/<v,v>.  The estimator's statistical properties
(near-unbiasedness, eq. 11 error bound) come from the random rotation, not the
search procedure, and are validated in tests/test_rabitq.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["RabitqCodes", "quantize", "dequantize", "estimate_matmul", "C_ERROR"]

# Empirical constant of the RaBitQ error bound (paper eq. 11), P >= 99.9%:
#   |<x,y> - est| < C_ERROR / (sqrt(d) * 2^b) * ||x|| * ||y||
C_ERROR = 5.75


class RabitqCodes(NamedTuple):
    """Quantized representation of a (d, c) matrix of column vectors."""
    codes: jax.Array    # (d, c) unsigned integer codes in [0, 2^b - 1]
    rescale: jax.Array  # (c,) per-column least-squares rescale factor
    bits: int           # static bit width b


def _candidate_errs(w: jax.Array, delta: jax.Array, c_b: float, levels: int):
    """Residual energy of LS-rescaled reconstruction for one grid step.

    err = ||w||^2 - <w,v>^2/<v,v>,  v = clip(round(w/delta + c_b), 0, L) - c_b.
    Returns (err, wv, vv) with shapes (c,).
    """
    v = jnp.clip(jnp.round(w / delta + c_b), 0.0, float(levels)) - c_b
    wv = jnp.sum(w * v, axis=0)
    vv = jnp.sum(v * v, axis=0)
    err = -(wv * wv) / jnp.maximum(vv, 1e-30)
    return err, wv, vv


def quantize(w: jax.Array, bits: int, n_candidates: int = 12,
             lo: float = 0.3, hi: float = 1.05) -> RabitqCodes:
    """Quantize columns of ``w`` (d, c) to ``bits``-bit codes + rescale.

    Grid-step candidates are ``geomspace(lo, hi, n_candidates) * delta0`` where
    ``delta0 = max|w_j| / c_b`` maps the column's max magnitude onto the grid
    edge.  Smaller steps clip the tails but resolve the bulk finer — the best
    trade is column-dependent, hence the per-column argmin.
    """
    if not (1 <= bits <= 8):
        raise ValueError(f"bits must be in [1, 8], got {bits}")
    w = w.astype(jnp.float32)
    levels = (1 << bits) - 1
    c_b = levels / 2.0
    absmax = jnp.max(jnp.abs(w), axis=0)                      # (c,)
    delta0 = jnp.maximum(absmax, 1e-30) / c_b
    scales = jnp.geomspace(lo, hi, n_candidates, dtype=jnp.float32)

    def eval_scale(s):
        err, _, _ = _candidate_errs(w, delta0 * s, c_b, levels)
        return err

    errs = jax.lax.map(eval_scale, scales)                    # (S, c)
    best = jnp.argmin(errs, axis=0)                           # (c,)
    delta = delta0 * scales[best]                             # (c,)
    v = jnp.clip(jnp.round(w / delta + c_b), 0.0, float(levels)) - c_b
    wv = jnp.sum(w * v, axis=0)
    vv = jnp.sum(v * v, axis=0)
    rescale = jnp.where(vv > 0, wv / jnp.maximum(vv, 1e-30), 0.0)
    codes = (v + c_b).astype(jnp.uint8)
    return RabitqCodes(codes=codes, rescale=rescale.astype(jnp.float32), bits=bits)


def dequantize(q: RabitqCodes) -> jax.Array:
    """Reconstruct w_hat = r * (codes - c_b) per column, shape (d, c)."""
    c_b = ((1 << q.bits) - 1) / 2.0
    return (q.codes.astype(jnp.float32) - c_b) * q.rescale[None, :]


def estimate_matmul(x: jax.Array, q: RabitqCodes) -> jax.Array:
    """Estimate X @ W from codes (paper Alg. 3, sans the external RHT).

    Y = (X @ codes) * r - z * r,  z = c_b * (X @ 1)   — the z-trick keeps the
    integer-code matmul free of the c_b offset so kernels can consume packed
    unsigned codes directly.
    """
    c_b = ((1 << q.bits) - 1) / 2.0
    xw = x.astype(jnp.float32) @ q.codes.astype(jnp.float32)   # (n, c)
    z = c_b * jnp.sum(x.astype(jnp.float32), axis=-1, keepdims=True)  # (n, 1)
    return (xw - z) * q.rescale[None, :]
