"""QuantizedLinear — the deployable artifact of RaanA for one linear layer.

Bundles everything Alg. 2 emits (packed codes, rescale r, Rademacher signs)
plus the App. C.3 trick state (mean column s, outlier rows) and applies
Alg. 3 at inference.  Registered as a JAX pytree so a quantized model is just
the original param tree with weight arrays swapped for QuantizedLinear nodes —
model code calls ``repro.models.common.linear`` which dispatches on type.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import hadamard, packing, rabitq, tricks

__all__ = ["QuantizedLinear", "quantize_linear", "reconstruct_weight",
           "QuantizedGrouped", "quantize_grouped"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedLinear:
    # --- dynamic leaves ---
    packed: jax.Array                 # (packed_rows(d_keep), c) uint8
    rescale: jax.Array                # (c,) f16
    signs1: jax.Array                 # (d_hat,) f32 (+/-1)
    signs2: Optional[jax.Array]       # (d_hat,) f32 or None (d_keep a pow2)
    mean_col: Optional[jax.Array]     # (d_keep,) f16 (centralization) or None
    w_out: Optional[jax.Array]        # (k, c) f16 outlier rows or None
    out_idx: Optional[jax.Array]      # (k,) int32 or None
    keep_idx: Optional[jax.Array]     # (d_keep,) int32 or None (k == 0)
    # --- static metadata ---
    bits: int = dataclasses.field(metadata=dict(static=True), default=4)
    d: int = dataclasses.field(metadata=dict(static=True), default=0)
    d_keep: int = dataclasses.field(metadata=dict(static=True), default=0)
    c: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def shape(self):  # mimic a weight array's (d, c)
        return (self.d, self.c)

    @property
    def dtype(self):
        return jnp.float32

    def overhead_bits(self) -> int:
        """Side-information cost in bits, at actual storage width (counted
        against the AllocateBits budget; signs are 1 bit each)."""
        n = self.rescale.size * self.rescale.dtype.itemsize * 8 + self.signs1.size
        if self.signs2 is not None:
            n += self.signs2.size
        if self.mean_col is not None:
            n += self.mean_col.size * self.mean_col.dtype.itemsize * 8
        if self.w_out is not None:
            n += (self.w_out.size * self.w_out.dtype.itemsize * 8
                  + self.out_idx.size * 32)
        return int(n)

    def apply(self, x: jax.Array) -> jax.Array:
        """Estimate x @ W for x of shape (..., d) — Alg. 3 + trick corrections.

        The RHT + dequant GEMM is one fused dispatch (kernels/qmatmul/ops):
        rotated activations stay in VMEM on the kernel path.  The output
        width is derived from ``rescale`` rather than the static ``c``:
        under tensor-parallel serving (runtime/tp.py) the dynamic leaves
        arrive column-sliced inside ``shard_map`` while the static metadata
        keeps the full-width values, and every column's estimator is
        independent, so the sliced apply is exact on its slice."""
        lead = x.shape[:-1]
        c = self.rescale.shape[-1]        # per-shard width (== self.c at TP=1)
        x2 = x.reshape(-1, self.d).astype(jnp.float32)
        if self.out_idx is not None and self.out_idx.size:
            x_out = jnp.take(x2, self.out_idx, axis=1)
            x_rest = jnp.take(x2, self.keep_idx, axis=1)
        else:
            x_out, x_rest = None, x2
        y = jnp.zeros((x2.shape[0], c), jnp.float32)
        if self.mean_col is not None:
            y = y + (x_rest @ self.mean_col.astype(jnp.float32))[:, None]
        from repro.kernels.qmatmul import ops as qops  # late: avoid cycle
        y = y + qops.rht_quantized_matmul(x_rest, self.packed, self.rescale,
                                          self.signs1, self.signs2,
                                          bits=self.bits, d=self.d_keep)
        if x_out is not None:
            y = y + x_out @ self.w_out.astype(jnp.float32)
        return y.reshape(*lead, c)


def quantize_linear(w: jax.Array, bits: int, key: jax.Array,
                    x_col_norms: np.ndarray | None = None,
                    outlier_frac: float = 0.003,
                    centralize: bool = True,
                    n_candidates: int = 12) -> QuantizedLinear:
    """Alg. 2 (+ App. C.3 tricks) for one weight matrix (d, c)."""
    d, c = w.shape
    w = w.astype(jnp.float32)
    # 1) column-outlier excluding (input dims by calibrated activation norm)
    if x_col_norms is not None and outlier_frac > 0:
        out_idx, keep_idx = tricks.outlier_indices(np.asarray(x_col_norms), outlier_frac)
    else:
        out_idx = np.zeros((0,), np.int32)
        keep_idx = np.arange(d, dtype=np.int32)
    has_out = out_idx.size > 0
    w_out, w_rest = (tricks.split_outlier_dims(w, out_idx, keep_idx)
                     if has_out else (None, w))
    d_keep = int(keep_idx.size)
    # 2) centralization
    if centralize:
        w_rest, mean_col = tricks.centralize(w_rest)
    else:
        mean_col = None
    # 3) practical RHT along the input axis
    d_hat = hadamard.largest_pow2_leq(d_keep)
    k1, k2 = jax.random.split(key)
    signs1 = hadamard.rademacher(k1, d_hat)
    signs2 = hadamard.rademacher(k2, d_hat) if d_hat != d_keep else None
    w_rot = hadamard.practical_rht(w_rest, signs1, signs2, axis=0)
    # 4) extended RaBitQ
    q = rabitq.quantize(w_rot, bits, n_candidates=n_candidates)
    packed = packing.pack_codes(q.codes, bits)
    # side info lives in f16 so overhead_bits' 16-bit count is the real cost
    return QuantizedLinear(
        packed=packed, rescale=q.rescale.astype(jnp.float16),
        signs1=signs1, signs2=signs2,
        mean_col=(mean_col.astype(jnp.float16)
                  if mean_col is not None else None),
        w_out=w_out.astype(jnp.float16) if w_out is not None else None,
        out_idx=jnp.asarray(out_idx) if has_out else None,
        keep_idx=jnp.asarray(keep_idx) if has_out else None,
        bits=bits, d=d, d_keep=d_keep, c=c)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedGrouped:
    """Stacked per-expert quantization for MoE weights (E, d, c).

    Signs are shared across experts in a layer (same input space); rescale is
    per (expert, column).  Tricks (centralization/outliers) are omitted for the
    grouped form — expert matrices are small and the RHT does the heavy
    lifting; noted in DESIGN.md.
    """
    packed: jax.Array            # (E, packed_rows(d), c) uint8
    rescale: jax.Array           # (E, c) f16
    signs1: jax.Array            # (d_hat,)
    signs2: Optional[jax.Array]
    bits: int = dataclasses.field(metadata=dict(static=True), default=4)
    d: int = dataclasses.field(metadata=dict(static=True), default=0)
    c: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def shape(self):
        return (self.packed.shape[0], self.d, self.c)

    def overhead_bits(self) -> int:
        """Side-information cost in bits, at actual storage width."""
        n = self.rescale.size * self.rescale.dtype.itemsize * 8 + self.signs1.size
        if self.signs2 is not None:
            n += self.signs2.size
        return int(n)

    def apply(self, xbuf: jax.Array) -> jax.Array:
        """xbuf (E, C, d) -> (E, C, c): per-expert Alg. 3 estimate.

        Routes through the fused RHT+qmatmul dispatch (vmapped over experts);
        codes stay packed — no dense (E, d, c) dequant buffer is ever built."""
        from repro.kernels.qmatmul import ops as qops  # late: avoid cycle
        return qops.grouped_rht_quantized_matmul(
            xbuf.astype(jnp.float32), self.packed, self.rescale,
            self.signs1, self.signs2, bits=self.bits, d=self.d)


def quantize_grouped(w: jax.Array, bits: int, key: jax.Array,
                     n_candidates: int = 12) -> QuantizedGrouped:
    """Quantize stacked expert weights (E, d, c) with shared RHT signs."""
    e, d, c = w.shape
    d_hat = hadamard.largest_pow2_leq(d)
    k1, k2 = jax.random.split(key)
    signs1 = hadamard.rademacher(k1, d_hat)
    signs2 = hadamard.rademacher(k2, d_hat) if d_hat != d else None
    w_rot = hadamard.practical_rht(w.astype(jnp.float32), signs1, signs2, axis=1)

    def quant_one(we):
        q = rabitq.quantize(we, bits, n_candidates=n_candidates)
        return packing.pack_codes(q.codes, bits), q.rescale

    packed, rescale = jax.lax.map(quant_one, w_rot)
    return QuantizedGrouped(packed=packed,
                            rescale=rescale.astype(jnp.float16), signs1=signs1,
                            signs2=signs2, bits=bits, d=d, c=c)


def reconstruct_weight(q: QuantizedLinear) -> jax.Array:
    """Effective W_hat (d, c) implementing exactly the Alg. 3 estimator.

    Lets any unmodified fp forward pass evaluate the quantized model
    (tests assert apply() == x @ reconstruct_weight()).
    """
    codes = packing.unpack_codes(q.packed, q.bits, q.d_keep)
    c_b = ((1 << q.bits) - 1) / 2.0
    w_rot = (codes.astype(jnp.float32) - c_b) * q.rescale[None, :]
    w_rest = hadamard.practical_rht_inverse(w_rot, q.signs1, q.signs2, axis=0)
    if q.mean_col is not None:
        w_rest = w_rest + q.mean_col[:, None]
    if q.out_idx is not None and q.out_idx.size:
        w_hat = jnp.zeros((q.d, q.c), jnp.float32)
        w_hat = w_hat.at[q.keep_idx, :].set(w_rest)
        w_hat = w_hat.at[q.out_idx, :].set(q.w_out.astype(jnp.float32))
    else:
        w_hat = w_rest
    return w_hat
