"""Few-shot / zero-shot calibration (paper §4.2, eq. 23).

Per linear layer k we need three Frobenius norms:

    alpha_k = (1/sqrt(d_k)) * ||df/dH^(k)||_F * ||X^(k)||_F * ||W^(k)||_F

``df/dH`` is obtained *exactly* by differentiating the loss w.r.t. an additive
zero perturbation injected at each linear output (the LinearCtx mechanism in
repro.models.common); ||X|| and per-input-dim column norms (for the outlier
trick) come from the same pass's taps.  Calibration always runs the model in
unrolled mode — 5 samples (few-shot) or 1 synthetic sentence (zero-shot), a
handful of backward passes, exactly the paper's cost profile.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import LinearCtx

# The paper's zero-shot sentence (§4.2), repeated 100x.
ZERO_SHOT_SENTENCE = ("The curious fox leaped over the quiet stream, its "
                      "reflection rippling in the golden afternoon light. ")


@dataclasses.dataclass
class LayerStat:
    name: str
    d: int
    c: int
    m: int                    # parameter count (per-layer; grouped: E*d*c)
    alpha: float              # eq. 23 sensitivity
    x_col_sq: np.ndarray      # (d,) accumulated input column energy
    grouped: bool = False
    n_groups: int = 1


def zero_shot_tokens(vocab: int, seq_len: int, repeats: int = 100) -> np.ndarray:
    """Byte-tokenized synthetic sentence (valid for any vocab >= 256)."""
    raw = (ZERO_SHOT_SENTENCE * repeats).encode("utf-8")
    toks = np.frombuffer(raw, dtype=np.uint8).astype(np.int32)
    if vocab < 256:
        toks = toks % vocab
    reps = -(-(seq_len + 1) // len(toks))
    return np.tile(toks, reps)[: seq_len + 1][None, :]


def calibrate(loss_with_ctx: Callable[[dict, dict, LinearCtx], jax.Array],
              params: dict, batches: list[dict]) -> dict[str, LayerStat]:
    """Estimate LayerStats over calibration batches.

    ``loss_with_ctx(params, batch, ctx)`` must run the model UNROLLED and
    route every linear through the ctx (models.transformer.loss_fn with
    scan=False does).
    """
    stats: dict[str, dict] = {}
    for batch in batches:
        # pass 1: taps (shapes + norms)
        ctx = LinearCtx(collect=True)
        _ = loss_with_ctx(params, batch, ctx)
        taps = {k: jax.tree.map(
            lambda v: np.asarray(v) if isinstance(v, jax.Array) else v, t)
            for k, t in ctx.taps.items()}
        # pass 2: grads w.r.t. output perturbations
        perturb0 = {k: jnp.zeros(t["h_shape"], jnp.float32)
                    for k, t in taps.items()}

        def loss_of_perturb(pert):
            return loss_with_ctx(params, batch, LinearCtx(perturb=pert))

        grads = jax.grad(loss_of_perturb)(perturb0)
        for name, tap in taps.items():
            g_fro = float(jnp.linalg.norm(grads[name].astype(jnp.float32)))
            x_fro = float(np.sqrt(tap["x_fro_sq"]))
            w_fro = float(tap["w_fro"])
            d = int(tap["d"])
            alpha = g_fro * x_fro * w_fro / np.sqrt(d)
            s = stats.setdefault(name, dict(
                alpha_sum=0.0, n=0, x_col_sq=np.zeros((d,), np.float64),
                d=d, c=int(tap["c"]), grouped=bool(tap.get("grouped", False)),
                n_groups=int(tap.get("n_groups", 1))))
            s["alpha_sum"] += alpha
            s["n"] += 1
            s["x_col_sq"] += np.asarray(tap["x_col_sq"], np.float64)
    out = {}
    for name, s in stats.items():
        m = s["d"] * s["c"] * s["n_groups"]
        out[name] = LayerStat(name=name, d=s["d"], c=s["c"], m=m,
                              alpha=s["alpha_sum"] / max(s["n"], 1),
                              x_col_sq=s["x_col_sq"], grouped=s["grouped"],
                              n_groups=s["n_groups"])
    return out
