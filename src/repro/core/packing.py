"""Bit-packing of RaBitQ codes.

Codes are stored packed along the d (input/contraction) axis:
  * bits in {1, 2, 4, 8}: dense — 8 // bits codes per uint8,
  * bits in {3, 5, 6, 7}: byte-aligned physically, counted at b logical bits
    for budget purposes (paper counts logical bits; physical density for
    non-power-of-2 widths is a storage-format detail orthogonal to the method).

The packed layout is what the qmatmul Pallas kernel consumes: codes travel
HBM -> VMEM packed and are unpacked in-register next to the MXU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pack_codes", "unpack_codes", "packed_rows", "DENSE_BITS"]

DENSE_BITS = (1, 2, 4, 8)


def packed_rows(d: int, bits: int) -> int:
    if bits not in DENSE_BITS:
        return d
    per = 8 // bits
    return (d + per - 1) // per


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack (d, c) uint8 codes -> (packed_rows(d, bits), c) uint8."""
    if bits not in DENSE_BITS or bits == 8:
        return codes.astype(jnp.uint8)
    per = 8 // bits
    d, c = codes.shape
    pad = (-d) % per
    if pad:
        codes = jnp.concatenate([codes, jnp.zeros((pad, c), codes.dtype)], axis=0)
    grp = codes.reshape(-1, per, c).astype(jnp.uint8)
    shifts = (jnp.arange(per, dtype=jnp.uint8) * bits)[None, :, None]
    return jnp.sum(grp << shifts, axis=1).astype(jnp.uint8)


def unpack_codes(packed: jax.Array, bits: int, d: int) -> jax.Array:
    """Inverse of ``pack_codes`` -> (d, c) uint8."""
    if bits not in DENSE_BITS or bits == 8:
        return packed
    per = 8 // bits
    mask = jnp.uint8((1 << bits) - 1)
    shifts = (jnp.arange(per, dtype=jnp.uint8) * bits)[None, :, None]
    grp = (packed[:, None, :] >> shifts) & mask
    return grp.reshape(-1, packed.shape[-1])[:d]
