"""Fast Walsh-Hadamard transform + the paper's practical RHT (App. A.1 / C.2).

TPU-native design note (DESIGN.md §3): instead of a log(d) butterfly (VPU-bound,
layout-hostile on TPU), we use the Kronecker factorization

    H_{d1*d2} = H_{d1} (x) H_{d2}

so a length-d FWHT is a reshape to (d1, d2) plus two *dense matmuls* with small
Hadamard matrices (d1, d2 <= 256) — exactly the shape the MXU wants.  The
Pallas kernel (repro/kernels/hadamard) keeps the tile in VMEM for both
contractions; this module is the pure-jnp implementation used as oracle and as
the CPU/dry-run path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "hadamard_matrix",
    "fwht",
    "rht",
    "rht_inverse",
    "practical_rht",
    "practical_rht_inverse",
    "rademacher",
    "largest_pow2_leq",
]


def largest_pow2_leq(d: int) -> int:
    """2 ** floor(log2(d))  (paper App. C.2: d_hat)."""
    if d < 1:
        raise ValueError(f"dimension must be >= 1, got {d}")
    return 1 << (d.bit_length() - 1)


@functools.lru_cache(maxsize=None)
def _hadamard_np(d: int) -> np.ndarray:
    """Unnormalized Sylvester Hadamard matrix H_d (d a power of 2)."""
    if d & (d - 1):
        raise ValueError(f"Hadamard matrix only defined for powers of 2, got {d}")
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < d:
        h = np.block([[h, h], [h, -h]])
    return h


def hadamard_matrix(d: int, dtype=jnp.float32) -> jax.Array:
    """Normalized (orthonormal, involutory) Hadamard matrix H_d / sqrt(d)."""
    return jnp.asarray(_hadamard_np(d) / math.sqrt(d), dtype=dtype)


def _split_dim(d: int) -> tuple[int, int]:
    """Balanced factorization d = d1 * d2 with both powers of 2, d1 >= d2.

    Factors are capped at 256 only implicitly (balanced split of d <= 2^16
    yields <= 256); matmul with a 256x256 H is still cheap.
    """
    lg = d.bit_length() - 1
    d1 = 1 << ((lg + 1) // 2)
    d2 = d // d1
    return d1, d2


def fwht(x: jax.Array, axis: int = -1) -> jax.Array:
    """Normalized fast Walsh-Hadamard transform along ``axis``.

    Length along ``axis`` must be a power of 2.  Orthonormal and involutory:
    ``fwht(fwht(x)) == x``.
    """
    axis = axis % x.ndim
    d = x.shape[axis]
    if d & (d - 1):
        raise ValueError(f"fwht requires a power-of-2 length, got {d}")
    if d == 1:
        return x
    x = jnp.moveaxis(x, axis, -1)
    lead = x.shape[:-1]
    d1, d2 = _split_dim(d)
    # row-major pairing: index i in [0,d) <-> (i1, i2), i1 slow => H_d = H_d1 (x) H_d2
    xr = x.reshape(*lead, d1, d2)
    h1 = hadamard_matrix(d1, x.dtype)
    h2 = hadamard_matrix(d2, x.dtype)
    xr = jnp.einsum("...ij,jk->...ik", xr, h2)
    xr = jnp.einsum("...ij,ia->...aj", xr, h1)
    x = xr.reshape(*lead, d)
    return jnp.moveaxis(x, -1, axis)


def rademacher(key: jax.Array, d: int, dtype=jnp.float32) -> jax.Array:
    """i.i.d. +/-1 vector of length d."""
    return (jax.random.bernoulli(key, 0.5, (d,)).astype(dtype) * 2.0 - 1.0)


def rht(x: jax.Array, signs: jax.Array, axis: int = -1) -> jax.Array:
    """Randomized Hadamard transform: x -> Hadamard(D x) (paper eq. 8)."""
    axis = axis % x.ndim
    shape = [1] * x.ndim
    shape[axis] = x.shape[axis]
    return fwht(x * signs.reshape(shape).astype(x.dtype), axis=axis)


def rht_inverse(y: jax.Array, signs: jax.Array, axis: int = -1) -> jax.Array:
    """Inverse of ``rht``: x = D Hadamard(y)  (H orthonormal involution)."""
    axis = axis % y.ndim
    shape = [1] * y.ndim
    shape[axis] = y.shape[axis]
    return fwht(y, axis=axis) * signs.reshape(shape).astype(y.dtype)


def _apply_block(x: jax.Array, signs: jax.Array, axis: int, start: int, d_hat: int,
                 inverse: bool) -> jax.Array:
    """Apply (inverse) RHT to the slice [start, start+d_hat) along ``axis``."""
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(start, start + d_hat)
    sl = tuple(sl)
    blk = x[sl]
    blk = rht_inverse(blk, signs, axis) if inverse else rht(blk, signs, axis)
    return x.at[sl].set(blk)


def practical_rht(x: jax.Array, signs1: jax.Array, signs2: jax.Array | None,
                  axis: int = -1) -> jax.Array:
    """Practical RHT for arbitrary dimension d (paper Alg. 5).

    d_hat = 2^floor(log2 d); RHT the first d_hat coords with D1, then the last
    d_hat coords with D2 (overlap is transformed twice; composition of
    orthogonal maps => inner products along ``axis`` are preserved exactly).
    When d is a power of 2 a single application suffices (signs2 may be None).
    """
    axis = axis % x.ndim
    d = x.shape[axis]
    d_hat = largest_pow2_leq(d)
    x = _apply_block(x, signs1, axis, 0, d_hat, inverse=False)
    if d_hat != d:
        if signs2 is None:
            raise ValueError("signs2 required when d is not a power of 2")
        x = _apply_block(x, signs2, axis, d - d_hat, d_hat, inverse=False)
    return x


def practical_rht_inverse(y: jax.Array, signs1: jax.Array,
                          signs2: jax.Array | None, axis: int = -1) -> jax.Array:
    """Exact inverse of ``practical_rht`` (reverse order, inverse blocks)."""
    axis = axis % y.ndim
    d = y.shape[axis]
    d_hat = largest_pow2_leq(d)
    if d_hat != d:
        if signs2 is None:
            raise ValueError("signs2 required when d is not a power of 2")
        y = _apply_block(y, signs2, axis, d - d_hat, d_hat, inverse=True)
    y = _apply_block(y, signs1, axis, 0, d_hat, inverse=True)
    return y
