"""Pre-quantization tricks (paper App. C.3): invertible linear transforms that
reduce quantization error without changing the computed product.

Used by default (matching the paper's experimental configuration):
  * Centralization — subtract the mean column s = mean_j w_j from every column
    of W before quantizing; the exact correction (X s) 1^T is a cheap matvec
    at inference.  (Paper describes T on activations; for a weights-offline /
    activations-online system the weight-side form is the natural equivalent —
    see DESIGN.md §3.)
  * Column-outlier excluding — the top ``outlier_frac`` *input dimensions* by
    calibrated activation column norm bypass quantization: their weight rows
    are stored in fp16 and applied exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Centralized", "centralize", "split_outlier_dims", "outlier_indices"]


class Centralized(NamedTuple):
    w_centered: jax.Array  # (d, c)
    mean_col: jax.Array    # (d,) the exact mean column s


def centralize(w: jax.Array) -> Centralized:
    s = jnp.mean(w, axis=1)
    return Centralized(w - s[:, None], s)


def outlier_indices(col_norms: np.ndarray, frac: float) -> tuple[np.ndarray, np.ndarray]:
    """(outlier_idx, keep_idx) — top ``frac`` of input dims by activation norm.

    Host-side (numpy): the split is static metadata baked into the quantized
    layer.  Indices are sorted ascending so gathers stay cache/vmem friendly.
    """
    d = int(col_norms.shape[0])
    k = int(np.ceil(frac * d)) if frac > 0 else 0
    if k == 0:
        return np.zeros((0,), np.int32), np.arange(d, dtype=np.int32)
    out = np.argsort(col_norms)[::-1][:k]
    out = np.sort(out).astype(np.int32)
    keep = np.setdiff1d(np.arange(d, dtype=np.int32), out, assume_unique=True)
    return out, keep


def split_outlier_dims(w: jax.Array, out_idx: np.ndarray, keep_idx: np.ndarray):
    """Split weight rows into (W_outlier (k, c) fp, W_rest (d', c))."""
    return w[out_idx, :], w[keep_idx, :]
