"""End-to-end RaanA pipeline (paper Alg. 1): calibrate -> AllocateBits ->
RaBitQ-H quantize -> deployable quantized param tree.

Two entry points:

  * ``quantize_model``          — the real pipeline: per-layer heterogeneous
    bit-widths from the DP allocator, outlier/centralization tricks, emits an
    unrolled ("layers" as python lists) quantized tree.
  * ``quantize_params_uniform`` — uniform-bit, trick-light variant that maps
    stacked layer trees to stacked QuantizedLinear leaves, preserving
    scan-over-layers (used by the multi-pod dry-run and large-scale serving;
    per-stack-position bit choice still allowed).

Weight categories (DESIGN.md §4): transformer-block 2-D projections and MoE
expert stacks are quantized; embeddings/lm_head, norms, routers, RWKV
token-shift/decay LoRAs, RG-LRU gate block-diagonals, conv filters, and
DeepSeek's wkv_b (needed in expanded form by the absorbed MLA decode) stay
in full precision.

The quantized artifact is tensor-parallel-ready by construction
(DESIGN.md §11): Alg. 3's estimator is column-separable — packed codes,
rescale, and w_out columns depend only on their own output column (the RHT
entangles *input rows*, which is exactly why TP shards by output column and
never by input row) — so serving places one quantization across any TP
degree by slicing leaves along the last axis (``runtime/tp.prepare_params``)
with no requantization and bit-identical per-column math.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf
from repro.models.config import ModelConfig

from . import allocate as alloc
from .calibrate import LayerStat
from .qlinear import (QuantizedGrouped, QuantizedLinear, quantize_grouped,
                      quantize_linear)

QUANTIZABLE_2D = {"wq", "wk", "wv", "wo", "wi", "swi", "swo", "ck", "cv",
                  "cr", "wr", "wg", "wq_a", "wq_b", "wkv_a"}
GROUPED_KEYS = {"wi", "wo"}


def _walk_layer(lp: dict, prefix: tuple = ()):
    """Yield (path, kind) for quantizable leaves of ONE layer's param dict."""
    for k, v in lp.items():
        path = prefix + (k,)
        if isinstance(v, dict):
            yield from _walk_layer(v, path)
        elif hasattr(v, "ndim"):
            if (len(path) >= 2 and path[-2] == "moe" and k in GROUPED_KEYS
                    and v.ndim == 3):
                yield path, "grouped"
            elif k in QUANTIZABLE_2D and v.ndim == 2 and min(v.shape) >= 8:
                yield path, "linear"


def _get(d: dict, path):
    for k in path:
        d = d[k]
    return d


def _set(d: dict, path, val):
    for k in path[:-1]:
        d = d[k]
    d[path[-1]] = val


@dataclass
class QuantReport:
    per_layer_bits: dict[str, int]
    avg_bits: float
    requested_avg_bits: float
    total_param_bits: int
    overhead_bits: int
    objective: float
    wall_time_s: float
    n_layers: int


def _overhead_bits_estimate(kind: str, shape, outlier_frac: float,
                            centralize: bool) -> int:
    """Side-info bits: rescale + signs + mean col + outlier rows/indices."""
    if kind == "grouped":
        e, d, c = shape
        return 16 * e * c + 2 * d
    d, c = shape
    k = int(np.ceil(outlier_frac * d)) if outlier_frac > 0 else 0
    bits = 16 * c + 2 * d                    # rescale + signs (both blocks)
    if centralize:
        bits += 16 * d
    bits += k * (16 * c + 32)
    return bits


def quantize_model(cfg: ModelConfig, params: dict,
                   stats: dict[str, LayerStat], avg_bits: float,
                   key: jax.Array, bit_choices=(1, 2, 3, 4, 5, 6, 7, 8),
                   outlier_frac: float = 0.003, centralize: bool = True,
                   n_candidates: int = 12):
    """Full RaanA: returns (quantized params tree, QuantReport)."""
    t0 = time.time()
    pat, p_period = cfg.pattern, cfg.scan_period

    entries = []  # (name, jpos, idx, path, kind, shape)

    def collect(scope: str, stack_list, n_layers, pat_fn):
        for i in range(n_layers):
            jpos, idx = i % p_period, i // p_period
            if scope == "enc":
                jpos, idx = 0, i
            lp = (stack_list[jpos][idx] if isinstance(stack_list[jpos], list)
                  else jax.tree.map(lambda a: a[idx], stack_list[jpos]))
            for path, kind in _walk_layer(lp):
                w = _get(lp, path)
                name = f"{scope}{i}." + ".".join(path)
                entries.append((name, scope, jpos, idx, path, kind,
                                tuple(w.shape)))

    collect("L", params["layers"], cfg.n_layers, pat)
    if cfg.enc_dec:
        collect("enc", params["enc_layers"], cfg.n_enc_layers, None)

    ms, alphas, overheads = [], [], []
    for (name, scope, jpos, idx, path, kind, shape) in entries:
        m = int(np.prod(shape))
        st = stats.get(name)
        if st is None:
            alpha = float(np.sqrt(m))            # weight-only fallback
        else:
            alpha = max(st.alpha, 1e-12)
        ms.append(m)
        alphas.append(alpha)
        overheads.append(_overhead_bits_estimate(kind, shape, outlier_frac,
                                                 centralize))
    total_m = int(sum(ms))
    budget = int(np.floor(avg_bits * total_m)) - int(sum(overheads))
    allocation = alloc.allocate_bits(alphas, ms, budget, bit_choices)

    # ---- quantize, building unrolled per-layer lists ----
    def unroll(stacks, n_layers, scope):
        lists = [[] for _ in range(p_period if scope == "L" else 1)]
        for i in range(n_layers):
            jpos, idx = (i % p_period, i // p_period) if scope == "L" else (0, i)
            lp = (stacks[jpos][idx] if isinstance(stacks[jpos], list)
                  else jax.tree.map(lambda a: a[idx], stacks[jpos]))
            lists[jpos].append(jax.tree.map(lambda a: a, lp))  # shallow copy
        return lists

    qparams = dict(params)
    qparams["layers"] = unroll(params["layers"], cfg.n_layers, "L")
    if cfg.enc_dec:
        qparams["enc_layers"] = unroll(params["enc_layers"],
                                       cfg.n_enc_layers, "enc")

    per_layer_bits: dict[str, int] = {}
    used_bits = 0
    overhead_used = 0
    for (name, scope, jpos, idx, path, kind, shape), bits in zip(
            entries, allocation.bits):
        target = (qparams["layers"][jpos][idx] if scope == "L"
                  else qparams["enc_layers"][0][idx])
        w = _get(target, path)
        key, sub = jax.random.split(key)
        if kind == "grouped":
            q = quantize_grouped(w, bits, sub, n_candidates=n_candidates)
            overhead_used += q.overhead_bits()
        else:
            st = stats.get(name)
            x_col = (np.sqrt(np.maximum(st.x_col_sq, 0.0))
                     if st is not None else None)
            q = quantize_linear(w, bits, sub, x_col_norms=x_col,
                                outlier_frac=outlier_frac if x_col is not None
                                else 0.0,
                                centralize=centralize,
                                n_candidates=n_candidates)
            overhead_used += q.overhead_bits()
        _set(target, path, q)
        per_layer_bits[name] = bits
        used_bits += bits * int(np.prod(shape))

    report = QuantReport(
        per_layer_bits=per_layer_bits,
        avg_bits=(used_bits + overhead_used) / total_m,
        requested_avg_bits=avg_bits,
        total_param_bits=used_bits,
        overhead_bits=overhead_used,
        objective=allocation.objective,
        wall_time_s=time.time() - t0,
        n_layers=len(entries))
    return qparams, report


# ------------------------------------------------ dual (self-speculative)


def _alias_rotation(tq, dq):
    """Point every draft QuantizedLinear/Grouped's sign vectors at the
    target's buffers.  Both trees were quantized with the same PRNG key, so
    the values are already identical — aliasing just stores the rotation
    once (and makes the sharing checkable by identity in tests)."""
    def share(t, d):
        if isinstance(d, (QuantizedLinear, QuantizedGrouped)):
            return dataclasses.replace(d, signs1=t.signs1, signs2=t.signs2)
        return d
    is_q = lambda x: isinstance(x, (QuantizedLinear, QuantizedGrouped))
    return jax.tree.map(share, tq, dq, is_leaf=is_q)


def quantize_model_dual(cfg: ModelConfig, params: dict,
                        stats: dict[str, LayerStat], avg_bits: float,
                        draft_avg_bits: float, key: jax.Array, **kwargs):
    """Self-speculative pair: quantize the SAME weights twice from one
    calibration pass — a target-budget model plus an aggressively low-budget
    draft (e.g. ~4 vs ~2.2 avg bits).

    AllocateBits makes bit-width a free per-layer parameter, so the draft
    costs no extra calibration, no separate checkpoint, and no extra
    rotation state: both runs consume the same ``stats`` and the same PRNG
    ``key``, so every layer's Rademacher signs (the practical-RHT rotation)
    come out identical, and the draft's sign leaves are aliased to the
    target's.  Full-precision leaves (embeddings, norms, routers, lm_head)
    are shared by reference between the two trees, so the draft's marginal
    memory is just its packed codes + side info.  Returns
    ``(target_params, target_report, draft_params, draft_report)``; feed the
    pair to ``serve.PagedServer(..., draft_params=..., speculate=k)``.
    """
    tparams, treport = quantize_model(cfg, params, stats, avg_bits, key,
                                      **kwargs)
    dparams, dreport = quantize_model(cfg, params, stats, draft_avg_bits, key,
                                      **kwargs)
    dparams = _alias_rotation(tparams, dparams)
    return tparams, treport, dparams, dreport


# ------------------------------------------------- uniform / dry-run variant


def _quantize_stacked_linear(w: jax.Array, bits: int, key: jax.Array
                             ) -> QuantizedLinear:
    """(n, d, c) stacked weights -> QuantizedLinear with stacked leaves
    (sliceable by scan via tree.map(a[i]))."""
    n, d, c = w.shape
    keys = jax.random.split(key, n)
    qs = [quantize_linear(w[i], bits, keys[i], x_col_norms=None,
                          outlier_frac=0.0, centralize=True, n_candidates=8)
          for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *qs)


def _quantize_stacked_grouped(w: jax.Array, bits: int, key: jax.Array
                              ) -> QuantizedGrouped:
    n = w.shape[0]
    keys = jax.random.split(key, n)
    qs = [quantize_grouped(w[i], bits, keys[i], n_candidates=8)
          for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *qs)


def quantize_params_uniform(cfg: ModelConfig, params: dict, bits: int,
                            key: jax.Array) -> dict:
    """Uniform-bit quantization preserving stacked (scannable) layout.

    Works under jax.eval_shape (no data-dependent control flow), which is how
    the dry-run lowers the quantized serve path without materializing 100s of
    GB of weights.
    """
    qparams = dict(params)

    def do_stacks(stacks):
        out = []
        for st in stacks:
            st = jax.tree.map(lambda a: a, st)  # shallow structural copy

            def rec(d: dict, prefix=()):
                for k in list(d.keys()):
                    v = d[k]
                    path = prefix + (k,)
                    if isinstance(v, dict):
                        rec(v, path)
                    elif hasattr(v, "ndim"):
                        nonlocal key
                        if (len(path) >= 2 and path[-2] == "moe"
                                and k in GROUPED_KEYS and v.ndim == 4):
                            key, sub = jax.random.split(key)
                            d[k] = _quantize_stacked_grouped(v, bits, sub)
                        elif (k in QUANTIZABLE_2D and v.ndim == 3
                              and min(v.shape[1:]) >= 8):
                            key, sub = jax.random.split(key)
                            d[k] = _quantize_stacked_linear(v, bits, sub)

            rec(st)
            out.append(st)
        return out

    qparams["layers"] = do_stacks(params["layers"])
    if cfg.enc_dec:
        qparams["enc_layers"] = do_stacks(params["enc_layers"])
    return qparams
