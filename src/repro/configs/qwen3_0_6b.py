"""qwen3-0.6b [hf:Qwen/Qwen3-8B family; hf] — dense GQA with qk-norm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv=8, d_ff=3072, vocab=151936,
    head_dim=128, norm="rmsnorm", act="silu", pos="rope", rope_theta=1e6,
    qk_norm=True)

TINY = CONFIG.with_(name="qwen3-tiny", n_layers=2, d_model=64, n_heads=4,
                    n_kv=2, d_ff=128, vocab=256, head_dim=16)
