"""mixtral-8x7b [arXiv:2401.04088; hf] — 8-expert top-2 MoE with sliding-window
attention (window 4096 => ring KV cache, long_500k-capable)."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336, vocab=32000,
    head_dim=128, norm="rmsnorm", act="silu", pos="rope", rope_theta=1e6,
    window=4096, subquadratic=True,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336))

TINY = CONFIG.with_(name="mixtral-tiny", n_layers=2, d_model=64, n_heads=4,
                    n_kv=2, d_ff=128, vocab=256, head_dim=16, window=16,
                    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128))
