"""whisper-large-v3 [arXiv:2212.04356; unverified] — enc-dec audio backbone.

Conv frontend is a STUB per the assignment: input_specs() provides
precomputed mel-frame embeddings (B, 1500, d) for the encoder.  32 encoder +
32 decoder layers, MHA (kv = heads), GELU FFN, sinusoidal positions.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="whisper",
    n_layers=32, d_model=1280, n_heads=20, n_kv=20, d_ff=5120, vocab=51866,
    head_dim=64, norm="layernorm", act="gelu", pos="sinusoidal",
    enc_dec=True, n_enc_layers=32, n_audio_ctx=1500, frontend="audio_stub")

TINY = CONFIG.with_(name="whisper-tiny", n_layers=2, d_model=64, n_heads=4,
                    n_kv=4, d_ff=128, vocab=256, head_dim=16,
                    n_enc_layers=2, n_audio_ctx=30)
