"""yi-34b [arXiv:2403.04652; hf] — llama-arch GQA at 34B."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv=8, d_ff=20480, vocab=64000,
    head_dim=128, norm="rmsnorm", act="silu", pos="rope", rope_theta=5e6)

TINY = CONFIG.with_(name="yi-tiny", n_layers=3, d_model=112, n_heads=7,
                    n_kv=1, d_ff=320, vocab=256, head_dim=16)
