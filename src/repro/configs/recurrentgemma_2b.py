"""recurrentgemma-2b [arXiv:2402.19427; hf] — Griffin: RG-LRU + local attn 1:2.

Pattern: (rglru, rglru, attn) repeating over 26 layers; local attention window
2048, MQA (kv=1).  Sub-quadratic (bounded window + O(1) recurrent state) =>
runs the long_500k cell.
"""
from repro.models.config import ModelConfig

_PATTERN = tuple(("rglru", "rglru", "attn")[i % 3] for i in range(26))

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="rglru",
    n_layers=26, d_model=2560, n_heads=10, n_kv=1, d_ff=7680, vocab=256000,
    head_dim=256, norm="rmsnorm", act="silu", pos="rope", rope_theta=1e4,
    window=2048, mixer_pattern=_PATTERN, rglru_width=2560, subquadratic=True)

TINY = CONFIG.with_(
    name="recurrentgemma-tiny", n_layers=5, d_model=64, n_heads=2, n_kv=1,
    d_ff=128, vocab=256, head_dim=32, window=16, rglru_width=64,
    mixer_pattern=tuple(("rglru", "rglru", "attn")[i % 3] for i in range(5)))
