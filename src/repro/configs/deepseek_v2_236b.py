"""deepseek-v2-236b [arXiv:2405.04434; hf] — MLA (kv_lora 512) + MoE
(2 shared + 160 routed, top-6)."""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="mla_moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv=128, d_ff=1536, vocab=102400,
    head_dim=128, norm="rmsnorm", act="silu", pos="rope", rope_theta=1e4,
    mixer_pattern=("mla",) * 60,
    mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64,
                  v_head=128, n_heads=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2))

TINY = CONFIG.with_(
    name="deepseek-v2-tiny", n_layers=2, d_model=64, n_heads=4, n_kv=4,
    d_ff=96, vocab=256, head_dim=16, mixer_pattern=("mla",) * 2,
    mla=MLAConfig(q_lora=48, kv_lora=32, qk_nope=16, qk_rope=8, v_head=16,
                  n_heads=4),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, n_shared=1))
