"""qwen2-vl-2b [arXiv:2409.12191; hf] — VLM backbone with M-RoPE.

The vision frontend is a STUB per the assignment: input_specs() provides
token ids plus the (3, B, S) multimodal position ids the frontend would emit
(temporal / height / width streams).  mrope_section (16, 24, 24) over the 64
rotary channel pairs of head_dim 128, as in the HF config.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv=2, d_ff=8960, vocab=151936,
    head_dim=128, norm="rmsnorm", act="silu", pos="mrope", rope_theta=1e6,
    mrope_sections=(16, 24, 24), frontend="vision_stub")

TINY = CONFIG.with_(name="qwen2-vl-tiny", n_layers=2, d_model=64, n_heads=4,
                    n_kv=2, d_ff=128, vocab=256, head_dim=16,
                    mrope_sections=(2, 3, 3))
