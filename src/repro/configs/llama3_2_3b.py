"""llama3.2-3b [hf:meta-llama; unverified] — small llama3, dense GQA."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv=8, d_ff=8192, vocab=128256,
    head_dim=128, norm="rmsnorm", act="silu", pos="rope", rope_theta=5e5)

TINY = CONFIG.with_(name="llama3.2-tiny", n_layers=2, d_model=96, n_heads=6,
                    n_kv=2, d_ff=192, vocab=256, head_dim=16)
