"""internlm2-1.8b [arXiv:2403.17297; hf] — dense GQA transformer."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv=8, d_ff=8192, vocab=92544,
    head_dim=128, norm="rmsnorm", act="silu", pos="rope", rope_theta=1e6)

TINY = CONFIG.with_(name="internlm2-tiny", n_layers=2, d_model=64, n_heads=4,
                    n_kv=2, d_ff=128, vocab=256, head_dim=16)
