"""--arch registry: id -> ModelConfig (full + tiny smoke variant)."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "internlm2-1.8b": "internlm2_1_8b",
    "llama3.2-3b": "llama3_2_3b",
    "qwen3-0.6b": "qwen3_0_6b",
    "yi-34b": "yi_34b",
    "rwkv6-3b": "rwkv6_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "whisper-large-v3": "whisper_large_v3",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mixtral-8x7b": "mixtral_8x7b",
    # the paper's own family (bonus, not part of the assigned 40-cell matrix)
    "llama2-7b": "llama2_7b",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "llama2-7b")


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_tiny(arch: str) -> ModelConfig:
    return _module(arch).TINY
