"""rwkv6-3b "Finch" [arXiv:2404.05892; hf] — attention-free, data-dependent
decay; sub-quadratic => runs the long_500k cell."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv6",
    n_layers=32, d_model=2560, n_heads=40, n_kv=40, d_ff=8960, vocab=65536,
    head_dim=64, norm="layernorm", act="relu2", pos="none",
    mixer_pattern=("rwkv",) * 32, subquadratic=True)

TINY = CONFIG.with_(name="rwkv6-tiny", n_layers=2, d_model=64, n_heads=2,
                    n_kv=2, d_ff=128, vocab=256, head_dim=32,
                    mixer_pattern=("rwkv",) * 2)
