from .registry import ARCH_IDS, get_config, get_tiny  # noqa: F401
from .shapes import SHAPES, cell_applicable, input_specs  # noqa: F401
