"""The assigned input-shape cells and their ShapeDtypeStruct input_specs.

  train_4k     seq 4096,    global_batch 256  -> train_step
  prefill_32k  seq 32768,   global_batch 32   -> prefill
  decode_32k   ctx 32768,   global_batch 128  -> serve_step (1 token + cache)
  long_500k    ctx 524288,  global_batch 1    -> serve_step; sub-quadratic
                                                  archs only (DESIGN.md §4)

Modality frontends are stubs: input_specs provides the embeddings/position ids
the frontend would produce (whisper mel frames, qwen2-vl M-RoPE streams).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (skip noted per assignment)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("SKIP: pure full-attention arch — 500k decode has no "
                       "sub-quadratic path (DESIGN.md §4)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: str,
                activation_dtype=jnp.bfloat16) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    For "train": a loss_fn batch.  For "prefill": prefill inputs.  For
    "decode": decode_step token inputs (caches are built separately via
    jax.eval_shape over init_caches — see launch.dryrun).
    """
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    specs: dict[str, Any] = {}
    if cell.kind == "train":
        specs["tokens"] = _sds((b, s + 1), jnp.int32)
        if cfg.pos == "mrope":
            specs["positions"] = _sds((3, b, s + 1), jnp.int32)
        if cfg.enc_dec:
            specs["enc_embeds"] = _sds((b, cfg.n_audio_ctx, cfg.d_model),
                                       activation_dtype)
    elif cell.kind == "prefill":
        specs["tokens"] = _sds((b, s), jnp.int32)
        if cfg.pos == "mrope":
            specs["positions"] = _sds((3, b, s), jnp.int32)
        if cfg.enc_dec:
            specs["enc_embeds"] = _sds((b, cfg.n_audio_ctx, cfg.d_model),
                                       activation_dtype)
    else:  # decode
        specs["tokens"] = _sds((b, 1), jnp.int32)
        specs["pos"] = _sds((), jnp.int32)
    return specs


def concrete_inputs(cfg: ModelConfig, shape: str, key=None,
                    activation_dtype=jnp.float32) -> dict[str, Any]:
    """Small concrete batches matching input_specs (smoke tests)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape, activation_dtype)
    out = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            if k == "pos":
                out[k] = jnp.int32(0)
            elif k == "positions":
                out[k] = jnp.zeros(v.shape, jnp.int32) + jnp.arange(
                    v.shape[-1], dtype=jnp.int32)
            else:
                out[k] = jax.random.randint(key, v.shape, 0, cfg.vocab,
                                            dtype=jnp.int32)
        else:
            out[k] = jax.random.normal(key, v.shape, jnp.float32).astype(v.dtype)
    return out
