"""llama2-7b [arXiv:2307.09288] — the paper's own evaluation family (bonus
config beyond the assigned ten; used by the quality benchmarks' protocol)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=32, d_ff=11008, vocab=32000,
    head_dim=128, norm="rmsnorm", act="silu", pos="rope", rope_theta=1e4)

TINY = CONFIG.with_(name="llama2-tiny", n_layers=4, d_model=128, n_heads=4,
                    n_kv=4, d_ff=384, vocab=512, head_dim=32)
