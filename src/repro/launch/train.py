"""Training driver: real training on host devices (tiny/small models on CPU,
the same code path scales to the production mesh via --mesh production).

  PYTHONPATH=src python -m repro.launch.train --arch llama2-7b --tiny \
      --steps 300 --batch 16 --seq 128 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.registry import get_config, get_tiny
from repro.data import LMBatchLoader, make_corpus_tokens
from repro.models import transformer as tf
from repro.optim import adamw_init
from repro.runtime.fault import FaultTolerantLoop, LoopConfig
from repro.runtime.steps import make_train_step


def train(arch: str = "llama2-7b", tiny: bool = True, steps: int = 200,
          batch: int = 16, seq: int = 128, lr: float = 1e-3,
          warmup: int = 20, microbatches: int = 1, seed: int = 0,
          ckpt_dir: str | None = None, ckpt_every: int = 100,
          grad_compression: str | None = None, log_every: int = 20,
          params=None, corpus=None, inject_failure=None):
    cfg = get_tiny(arch) if tiny else get_config(arch)
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = tf.init_params(cfg, key)
    opt = adamw_init(params)
    step_fn = make_train_step(cfg, microbatches=microbatches, peak_lr=lr,
                              warmup=warmup, total_steps=steps,
                              grad_compression=grad_compression)
    jit_step = jax.jit(step_fn)

    if corpus is None:
        corpus = make_corpus_tokens(cfg.vocab, n_sentences=20000, seed=seed)
    loader = LMBatchLoader(corpus, batch, seq, seed=seed)

    losses = []

    def wrapped(state, batch_np):
        p, o = state
        b = {"tokens": jnp.asarray(batch_np)}
        p, o, m = jit_step(p, o, b)
        return (p, o), m

    def on_metrics(step, m, dt):
        losses.append(float(m["loss"]))
        if step % log_every == 0:
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} {dt*1e3:.0f}ms",
                  flush=True)

    state = (params, opt)
    start = 0
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=2)
        loop = FaultTolerantLoop(wrapped, mgr, LoopConfig(
            ckpt_every=ckpt_every), inject_failure=inject_failure)
        state, start = loop.maybe_resume(state)
        state = loop.run(state, lambda s: loader_batch(loader, s), steps,
                         start_step=start, on_metrics=on_metrics)
    else:
        for s in range(steps):
            state, m = wrapped(state, loader_batch(loader, s))
            on_metrics(s, m, 0.0)
    params, opt = state
    return cfg, params, losses


def loader_batch(loader: LMBatchLoader, step: int):
    loader.step = step
    return loader.next_batch()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--grad-compression", default=None)
    args = ap.parse_args()
    t0 = time.time()
    cfg, params, losses = train(
        arch=args.arch, tiny=args.tiny, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=args.lr, microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        grad_compression=args.grad_compression)
    print(f"trained {cfg.name}: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
