import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), record memory_analysis(),
cost_analysis(), and per-collective byte counts parsed from the optimized
per-device HLO.  Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>[__qN].json
— EXPERIMENTS.md §Dry-run / §Roofline tables are generated from these.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only] \
      [--quant 4] [--force]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cell_applicable, input_specs
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.pipeline import quantize_params_uniform
from repro.launch.mesh import make_production_mesh
from repro.models import decode as decmod
from repro.models import transformer as tf
from repro.optim import adamw_init
from repro.runtime import sharding as shd
from repro.runtime.steps import make_prefill_step, make_serve_step, make_train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-chip bytes moved by each collective kind (post-partition shapes).

    We count the RESULT shapes on the op line (for all-reduce/all-to-all/
    collective-permute result == operand; for all-gather the result is the
    gathered buffer — an upper bound on wire bytes; for reduce-scatter we
    count the operand = result x group size by scaling with the replica group
    size when parseable).
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+(\w[\w\-]*)\(", s)
        if not m:
            continue
        result_part, opname = m.groups()
        if opname.endswith("-done"):
            continue                      # async pair: count the -start only
        base = opname.removesuffix("-start")
        if base not in _COLLECTIVES:
            continue
        nbytes = _shape_bytes(result_part)
        if base == "reduce-scatter":
            g = re.search(r"replica_groups=\{\{([^}]*)\}", s)
            if g:
                group = len(g.group(1).split(","))
                nbytes *= group
        out[base] += nbytes
        counts[base] += 1
    out_total = sum(out.values())
    return {"bytes_by_kind": out, "counts": counts, "total_bytes": out_total}


def _abstract(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def build_cell(arch: str, shape: str, *, multi_pod: bool, quant: int | None,
               microbatches: int = 4, dtype=jnp.bfloat16,
               remat_attention: bool = False, seqshard: bool = False,
               expand_kv: bool = False, shard_kv: bool = False,
               shard_qkv: bool = False):
    """Returns (lower_fn, meta) for the cell; lower_fn() -> jax.stages.Lowered."""
    cfg = get_config(arch)
    if remat_attention:
        cfg = cfg.with_(remat_attention=True)
    if expand_kv:
        cfg = cfg.with_(expand_kv=True)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    serve = cell.kind != "train"
    from jax.sharding import PartitionSpec as P
    from repro.runtime import actsharding
    actsharding.POLICY.clear()
    if seqshard:
        actsharding.POLICY["hidden"] = P(shd.dp_axes(mesh), "model", None)
    if shard_kv:
        actsharding.POLICY["kv"] = P(shd.dp_axes(mesh), "model", None, None)
    if shard_qkv:
        actsharding.POLICY["qkv"] = P(shd.dp_axes(mesh), None, "model", None)

    params_sds = _abstract(lambda: tf.init_params(cfg, jax.random.PRNGKey(0),
                                                  dtype=dtype))
    if quant is not None and serve:
        params_sds = _abstract(
            lambda: quantize_params_uniform(
                cfg, tf.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype),
                quant, jax.random.PRNGKey(1)))
    p_specs = shd.named(shd.param_specs(params_sds, mesh, serve=serve), mesh)
    batch_sds = input_specs(cfg, shape, activation_dtype=dtype)
    b_specs = shd.named(shd.batch_specs(batch_sds, mesh), mesh)

    if cell.kind == "train":
        opt_sds = _abstract(adamw_init, params_sds)
        o_specs = shd.named(shd.param_specs(opt_sds, mesh, serve=False), mesh)
        step = make_train_step(cfg, microbatches=microbatches)

        def lower():
            with jax.set_mesh(mesh):
                return jax.jit(
                    step,
                    in_shardings=(p_specs, o_specs, b_specs),
                    out_shardings=(p_specs, o_specs, None),
                    donate_argnums=(0, 1),   # params/opt updated in place
                ).lower(params_sds, opt_sds, batch_sds)

    elif cell.kind == "prefill":
        step = make_prefill_step(cfg, context=cell.seq_len, cache_dtype=dtype)

        def lower():
            with jax.set_mesh(mesh):
                return jax.jit(
                    step, in_shardings=(p_specs, b_specs), out_shardings=None,
                ).lower(params_sds, batch_sds)

    else:  # decode
        b = cell.global_batch
        enc_out_sds = None
        if cfg.enc_dec:
            enc_out_sds = jax.ShapeDtypeStruct(
                (b, cfg.n_audio_ctx, cfg.d_model), dtype)
        if enc_out_sds is not None:
            caches_sds = _abstract(
                lambda p, e: decmod.init_caches(cfg, p, b, cell.seq_len,
                                                dtype, encoder_out=e),
                params_sds, enc_out_sds)
        else:
            caches_sds = _abstract(
                lambda p: decmod.init_caches(cfg, p, b, cell.seq_len, dtype),
                params_sds)
        c_specs = shd.named(shd.cache_specs(caches_sds, mesh), mesh)
        step = make_serve_step(cfg)
        tok_sds = batch_sds["tokens"]
        pos_sds = batch_sds["pos"]
        tok_spec = shd.named(shd.batch_specs({"tokens": tok_sds}, mesh),
                             mesh)["tokens"]

        def lower():
            with jax.set_mesh(mesh):
                return jax.jit(
                    step,
                    in_shardings=(p_specs, c_specs, tok_spec, None),
                    out_shardings=(None, c_specs),
                    donate_argnums=(1,),     # caches updated in place
                ).lower(params_sds, caches_sds, tok_sds, pos_sds)

    meta = dict(arch=arch, shape=shape, kind=cell.kind,
                mesh="2x16x16" if multi_pod else "16x16",
                chips=512 if multi_pod else 256,
                seq_len=cell.seq_len, global_batch=cell.global_batch,
                quant=quant, microbatches=microbatches if cell.kind == "train"
                else None)
    return lower, meta


def run_cell(arch: str, shape: str, *, multi_pod: bool, quant: int | None,
             force: bool = False, microbatches: int = 4,
             save_hlo: bool = False, remat_attention: bool = False,
             seqshard: bool = False, expand_kv: bool = False,
             shard_kv: bool = False, shard_qkv: bool = False,
             variant: str = "") -> dict:
    os.makedirs(ART_DIR, exist_ok=True)
    meshname = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape}__{meshname}" + (f"__q{quant}" if quant else "")
    if variant:
        tag += f"__{variant}" 
    path = os.path.join(ART_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec = dict(arch=arch, shape=shape, mesh=meshname, status="skip",
                   reason=why, quant=quant)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    t0 = time.time()
    try:
        lower_fn, meta = build_cell(arch, shape, multi_pod=multi_pod,
                                    quant=quant, microbatches=microbatches,
                                    remat_attention=remat_attention,
                                    seqshard=seqshard, expand_kv=expand_kv,
                                    shard_kv=shard_kv, shard_qkv=shard_qkv)
        meta["variant"] = variant
        lowered = lower_fn()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        # Loop-aware re-derivation (XLA cost_analysis counts while bodies
        # once — see launch/hlocost.py). These are the roofline inputs.
        from repro.launch.hlocost import analyze_hlo
        corrected = analyze_hlo(hlo)
        rec = dict(status="ok", **meta,
                   lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                   flops=corrected["flops"],
                   hlo_bytes=corrected["bytes"],
                   coll_bytes=corrected["coll_bytes"],
                   coll_by_kind=corrected["coll_by_kind"],
                   unknown_trip_whiles=corrected["unknown_trip_whiles"],
                   xla_flops_raw=cost.get("flops", -1.0),
                   xla_bytes_raw=cost.get("bytes accessed", -1.0),
                   cost_analysis={k: v for k, v in cost.items()
                                  if isinstance(v, (int, float))
                                  and len(k) < 40},
                   memory=dict(
                       argument=getattr(mem, "argument_size_in_bytes", 0),
                       output=getattr(mem, "output_size_in_bytes", 0),
                       temp=getattr(mem, "temp_size_in_bytes", 0),
                       peak=getattr(mem, "peak_memory_in_bytes", 0)),
                   collectives=coll)
        if save_hlo:
            with open(os.path.join(ART_DIR, tag + ".hlo"), "w") as f:
                f.write(hlo)
    except Exception as e:
        rec = dict(arch=arch, shape=shape, mesh=meshname, status="error",
                   quant=quant, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--quant", type=int, default=None)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--remat-attention", action="store_true")
    ap.add_argument("--seqshard", action="store_true")
    ap.add_argument("--expand-kv", action="store_true")
    ap.add_argument("--shard-kv", action="store_true")
    ap.add_argument("--shard-qkv", action="store_true")
    ap.add_argument("--variant", default="")
    args = ap.parse_args()

    cells = []
    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ([False, True] if args.both_meshes
              else [bool(args.multi_pod)])
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))
    n_ok = n_skip = n_err = 0
    for a, s, mp in cells:
        rec = run_cell(a, s, multi_pod=mp, quant=args.quant,
                       force=args.force, microbatches=args.microbatches,
                       save_hlo=args.save_hlo,
                       remat_attention=args.remat_attention,
                       seqshard=args.seqshard, expand_kv=args.expand_kv,
                       shard_kv=args.shard_kv, shard_qkv=args.shard_qkv,
                       variant=args.variant)
        status = rec["status"]
        n_ok += status == "ok"
        n_skip += status == "skip"
        n_err += status == "error"
        extra = ""
        if status == "ok":
            extra = (f"flops={rec['flops']:.3e} bytes={rec['hlo_bytes']:.3e} "
                     f"coll={rec['coll_bytes']:.3e} "
                     f"peak={rec['memory']['peak']/2**30:.2f}GiB "
                     f"compile={rec['compile_s']}s")
        elif status == "error":
            extra = rec["error"][:160]
        print(f"[{status:5s}] {a} {s} {rec['mesh']}"
              + (f" q{args.quant}" if args.quant else "") + " " + extra,
              flush=True)
    print(f"done: {n_ok} ok, {n_skip} skip, {n_err} error")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
