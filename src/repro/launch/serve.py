"""Serving CLI: continuous-batching paged engine (default) or the lockstep
baseline, with optional RaanA-quantized weights — the deployment artifact of
the paper.

The paged engine (repro.serve) runs a block-arena KV pool with per-request
block tables: requests are admitted against free blocks, prompts prefill in
chunks interleaved with decode, and completed requests free their slot
immediately.  Shared prompt prefixes are served from the content-addressed
prefix cache (``--no-prefix-cache`` for a cold pool A/B; the printed
``prefix_hit_rate`` is the fraction of prompt tokens whose prefill was
skipped), and ``--kv-dtype bf16`` halves the KV arena bytes.  ``--lockstep``
keeps the legacy ``BatchedServer`` behavior (aligned prefill, whole-batch
decode until the last request finishes) as the A/B baseline.  ``--unfused``
restores the two-kernel RHT+qmatmul composition (rotated activations
round-trip through HBM) for A/B measurement, and ``--paged-kernel`` /
``--no-paged-kernel`` pins the decode attention read to the Pallas
flash-decode kernel over the block arena vs the dense gather path
(DESIGN.md §10; unset, the backend decides).  ``--speculate K`` turns on
self-speculative decoding: the same weights are quantized a second time at
``--draft-bits`` (sharing the calibration pass and Hadamard rotation with
the target quantization) and the engine runs draft-propose/target-verify
rounds — greedy outputs stay token-identical, and the printed
``acceptance_rate`` tracks how many draft tokens survive verification.
``--tp N`` serves tensor-parallel over a ``(data, model)`` mesh
(DESIGN.md §11): quantized columns, attention heads, and the KV arena
shard over N chips and greedy outputs stay token-identical to ``--tp 1``;
on CPU, force devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

``--serve`` switches from the one-shot batch run to the streaming front
door (DESIGN.md §12): an HTTP server on ``--port`` exposing
``POST /v1/generate`` with per-token SSE, per-tenant priority admission
with weighted fair sharing (``--max-tenant-share`` caps one tenant's slot
fraction), drop-and-replay preemption, and — with ``--slo-p95-ms`` set —
an SLO controller that throttles chunked-prefill admission when the
decode-gap p95 exceeds the target.  The engine knobs above (slots, block
size, quantization, speculation, tp) all apply to the served engine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --tiny \
      --avg-bits 3.3 --requests 8 --gen 32

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --tiny \
      --avg-bits 4.0 --speculate 3 --draft-bits 2.2 --requests 4 --gen 16

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --tiny \
      --serve --port 8080 --slo-p95-ms 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_tiny
from repro.core import calibrate as cal
from repro.core import pipeline as pipe
from repro.data import ByteTokenizer
from repro.kernels.paged_attention import ops as pops
from repro.kernels.qmatmul import ops as qops
from repro.models import decode as decmod
from repro.models import transformer as tf
from repro.serve import PagedServer, PoolConfig, Request


class BatchedServer:
    """Minimal batched LM server: aligned prefill + lockstep decode.

    All requests prefill together and decode in lockstep until the batch's
    last request finishes — the baseline the paged engine is measured
    against.  Greedy or temperature sampling; quantized models route every
    linear through Alg. 3 (QuantizedLinear.apply) transparently.
    """

    def __init__(self, cfg, params, max_context: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_context = max_context
        self._decode = jax.jit(
            lambda p, c, t, pos: decmod.decode_step(cfg, p, c, t, pos,
                                                    scan=False))

    def generate(self, prompts: np.ndarray, n_tokens: int,
                 temperature: float = 0.0, key=None):
        """prompts (B, S) int32 -> (B, n_tokens) int32."""
        b, s = prompts.shape
        logits, caches, pos = decmod.prefill(
            self.cfg, self.params, jnp.asarray(prompts),
            context=self.max_context, scan=False)
        last = logits[:, -1, :]
        out = []
        key = key if key is not None else jax.random.PRNGKey(0)
        for t in range(n_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, last / temperature, axis=-1)
            else:
                tok = jnp.argmax(last, axis=-1)
            out.append(tok)
            last, caches = self._decode(self.params, caches, tok[:, None],
                                        jnp.int32(s + t))
        return np.stack([np.asarray(t) for t in out], axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--avg-bits", type=float, default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--lockstep", action="store_true",
                    help="legacy whole-batch server (A/B baseline)")
    ap.add_argument("--unfused", action="store_true",
                    help="disable RHT+qmatmul fusion (A/B baseline)")
    ap.add_argument("--slots", type=int, default=4,
                    help="paged engine: concurrent request slots")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged engine: tokens per KV block")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="paged engine: prompt tokens per scheduler turn")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="content-addressed KV prefix reuse (paged engine; "
                         "auto-bypassed for windowed/recurrent archs)")
    ap.add_argument("--kv-dtype", choices=["f32", "bf16"], default="f32",
                    help="paged engine: KV arena + slot-state dtype")
    ap.add_argument("--paged-kernel", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="route paged attention through the Pallas "
                         "flash-decode kernel over the block arena "
                         "(interpret-mode off TPU); --no-paged-kernel "
                         "forces the dense gather path; default lets the "
                         "backend decide (kernel on TPU)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="self-speculative decoding: draft K tokens per "
                         "round from a low-bit quantization of the same "
                         "weights, verify them in one target step (paged "
                         "engine; attention archs only — recurrent/MLA "
                         "bypass)")
    ap.add_argument("--draft-bits", type=float, default=2.2,
                    help="average bit budget for the speculative draft "
                         "quantization (used when --speculate > 0)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: serve over a "
                         "(data, model) mesh with this many chips on the "
                         "model axis (paged engine; must divide the device "
                         "count — on CPU force devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    ap.add_argument("--serve", action="store_true",
                    help="boot the streaming HTTP/SSE front door on --port "
                         "instead of the one-shot batch run (paged engine; "
                         "POST /v1/generate, GET /healthz, GET /v1/stats); "
                         "--prompt-len + --gen size the pool's max context")
    ap.add_argument("--port", type=int, default=8080,
                    help="front door listen port (0 binds an ephemeral "
                         "port; the chosen one is printed at boot)")
    ap.add_argument("--slo-p95-ms", type=float, default=None,
                    help="front door: decode-gap p95 target in ms — past "
                         "it the scheduler throttles chunked-prefill "
                         "admission until p95 recovers (default: "
                         "controller off)")
    ap.add_argument("--max-tenant-share", type=float, default=1.0,
                    help="front door: max fraction of engine slots one "
                         "tenant may hold while other tenants wait "
                         "(default 1.0 = uncapped)")
    args = ap.parse_args()
    if args.speculate and args.lockstep:
        ap.error("--speculate needs the paged engine (drop --lockstep)")
    if args.tp > 1 and args.lockstep:
        ap.error("--tp needs the paged engine (drop --lockstep)")
    if args.serve and args.lockstep:
        ap.error("--serve needs the paged engine (drop --lockstep)")

    cfg = get_tiny(args.arch) if args.tiny else get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)

    draft_params = None
    if args.avg_bits or args.speculate:
        stats_msg = (f"{args.avg_bits} avg bits" if args.avg_bits
                     else "fp32 target")
        print(f"calibrating + quantizing ({stats_msg}"
              + (f", {args.draft_bits}-bit draft" if args.speculate else "")
              + ") ...")
        toks = cal.zero_shot_tokens(cfg.vocab, 256)
        stats = cal.calibrate(
            lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
            params, [{"tokens": jnp.asarray(toks)}])
        if args.avg_bits and args.speculate:
            params, rep, draft_params, drep = pipe.quantize_model_dual(
                cfg, params, stats, args.avg_bits, args.draft_bits,
                jax.random.PRNGKey(1))
            print(f"quantized {rep.n_layers} layers, achieved "
                  f"{rep.avg_bits:.3f} target / {drep.avg_bits:.3f} draft "
                  f"bits in {rep.wall_time_s + drep.wall_time_s:.1f}s")
        elif args.avg_bits:
            params, rep = pipe.quantize_model(cfg, params, stats,
                                              args.avg_bits,
                                              jax.random.PRNGKey(1))
            print(f"quantized {rep.n_layers} layers, achieved "
                  f"{rep.avg_bits:.3f} bits in {rep.wall_time_s:.1f}s")
        else:   # fp32 target, quantized draft
            draft_params, drep = pipe.quantize_model(
                cfg, params, stats, args.draft_bits, jax.random.PRNGKey(1))
            print(f"quantized draft: {drep.avg_bits:.3f} bits in "
                  f"{drep.wall_time_s:.1f}s")

    tok = ByteTokenizer(cfg.vocab)
    prompt = tok.encode("the quick brown fox " * 8)[: args.prompt_len]
    t0 = time.time()
    if args.lockstep:
        with qops.fusion(not args.unfused):
            server = BatchedServer(cfg, params,
                                   max_context=args.prompt_len + args.gen)
            prompts = np.stack([prompt for _ in range(args.requests)])
            out = server.generate(prompts, args.gen)
        sample = out[0]
        extra = "lockstep"
    else:
        pool = PoolConfig(max_slots=args.slots, block_size=args.block_size,
                          max_context=args.prompt_len + args.gen,
                          prefill_chunk=args.prefill_chunk,
                          prefix_cache=args.prefix_cache,
                          kv_dtype=(jnp.bfloat16 if args.kv_dtype == "bf16"
                                    else jnp.float32))
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(tp=args.tp) if args.tp > 1 else None
        engine = PagedServer(cfg, params, pool, fused=not args.unfused,
                             paged_kernel=args.paged_kernel,
                             draft_params=draft_params,
                             speculate=args.speculate, mesh=mesh)
        if args.serve:
            from repro.serve.frontdoor import FrontDoor, SchedConfig
            door = FrontDoor(
                engine,
                SchedConfig(slo_p95_ms=args.slo_p95_ms,
                            max_tenant_share=args.max_tenant_share),
                port=args.port)
            door.serve_forever()
            return
        results = engine.run([Request(rid=i, prompt=np.asarray(prompt),
                                      max_new=args.gen)
                              for i in range(args.requests)])
        sample = results[0].tokens
        with pops.paged_kernel(args.paged_kernel):
            attn_path = "kernel" if pops.kernel_enabled() else "gather"
        m = engine.mesh.shape
        extra = (f"paged, occupancy={engine.stats['mean_occupancy']:.2f}, "
                 f"decode_traces={engine.decode_trace_count}, "
                 f"attn={attn_path}, "
                 f"mesh={m['data']}x{m['model']}, tp={engine.tp}")
        if engine.speculate:
            extra += (f", speculate={engine.speculate}, acceptance_rate="
                      f"{engine.stats['acceptance_rate']:.2f}")
        if engine.prefix_cache is not None:
            extra += (f", prefix_hit_rate="
                      f"{engine.stats['prefix_hit_rate']:.2f}, "
                      f"prefill_tokens_saved="
                      f"{engine.stats.get('prefill_tokens_saved', 0)}")
    dt = time.time() - t0
    path = "unfused" if args.unfused else "fused"
    print(f"served {args.requests} requests x {args.gen} tokens in {dt:.2f}s "
          f"({args.requests*args.gen/dt:.1f} tok/s, {path} decode path, "
          f"{extra})")
    print("sample:", tok.decode(np.asarray(sample))[:80])


if __name__ == "__main__":
    main()
