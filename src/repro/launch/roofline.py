"""Roofline analysis over dry-run artifacts (deliverable g).

Hardware constants (TPU v5e-class, per chip):
    peak 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

cost_analysis() of the compiled artifact is PER-DEVICE (the partitioned
module), so the three terms are computed directly per chip:

    T_compute = flops / PEAK_FLOPS
    T_memory  = bytes_accessed / HBM_BW
    T_coll    = collective_bytes / ICI_BW

MODEL_FLOPS (the "useful work" yardstick):
    train : 6 * N_active * tokens        (fwd 2ND + bwd 4ND)
    decode: 2 * N_active * batch         (one token per sequence)
    prefill: 2 * N_active * tokens
divided by chips for the per-device comparison against HLO flops.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

import jax
import numpy as np

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts (excluding embed/lm_head for the
    6ND convention)."""
    from repro.models import transformer as tf
    sds = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    total = active = 0
    moe_total = moe_active = 0
    leaves = jax.tree_util.tree_flatten_with_path(sds)[0]
    for path, leaf in leaves:
        names = [str(getattr(e, "key", "")) for e in path]
        n = int(np.prod(leaf.shape))
        if "embed" in names or "lm_head" in names:
            continue
        total += n
        if "moe" in names and names[-1] in ("wi", "wo"):
            moe_total += n
            e = cfg.moe.n_experts
            moe_active += n * cfg.moe.top_k // e
        else:
            active += n
    return total + moe_total, active + moe_active


@dataclass
class Roofline:
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops_per_chip: float
    useful_ratio: float      # MODEL_FLOPS / HLO_FLOPs (per chip)
    roofline_frac: float     # max-term time vs bound from useful work

    def as_dict(self):
        return dict(t_compute=self.t_compute, t_memory=self.t_memory,
                    t_collective=self.t_collective, dominant=self.dominant,
                    model_flops_per_chip=self.model_flops_per_chip,
                    useful_ratio=self.useful_ratio,
                    roofline_frac=self.roofline_frac)


def model_flops(cfg, kind: str, seq_len: int, global_batch: int) -> float:
    total, active = count_params(cfg)
    if kind == "train":
        return 6.0 * active * seq_len * global_batch
    if kind == "prefill":
        return 2.0 * active * seq_len * global_batch
    return 2.0 * active * global_batch          # decode: one token/seq


def analyze(rec: dict, cfg) -> Roofline:
    chips = rec["chips"]
    t_c = rec["flops"] / PEAK_FLOPS
    t_m = rec["hlo_bytes"] / HBM_BW
    t_l = rec.get("coll_bytes",
                  rec.get("collectives", {}).get("total_bytes", 0)) / ICI_BW
    dominant = max(("compute", t_c), ("memory", t_m),
                   ("collective", t_l), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, rec["kind"], rec["seq_len"], rec["global_batch"])
    mf_chip = mf / chips
    useful = mf_chip / max(rec["flops"], 1.0)
    # the time a perfect implementation of the useful work would need
    t_useful = max(mf_chip / PEAK_FLOPS,
                   _min_bytes(cfg, rec) / HBM_BW)
    frac = t_useful / max(t_c, t_m, t_l, 1e-30)
    return Roofline(t_compute=t_c, t_memory=t_m, t_collective=t_l,
                    dominant=dominant, model_flops_per_chip=mf_chip,
                    useful_ratio=useful, roofline_frac=min(frac, 1.0))


def _min_bytes(cfg, rec) -> float:
    """Lower bound on per-chip bytes: weights touched once (+cache for
    decode).  bf16 unless quantized codes."""
    total, active = count_params(cfg)
    chips = rec["chips"]
    wbytes = 2.0 * total
    if rec.get("quant"):
        wbytes = total * rec["quant"] / 8.0
    per_chip = wbytes / chips
    return per_chip


def load_records(mesh: str = "16x16", quant=None, variant: str = ""
                 ) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if r.get("mesh") != mesh:
            continue
        if (r.get("quant") or None) != quant:
            continue
        if (r.get("variant") or "") != variant:
            continue
        recs.append(r)
    return recs


def report(mesh: str = "16x16", quant=None) -> str:
    from repro.configs.registry import get_config
    rows = []
    hdr = (f"{'arch':20s} {'shape':12s} {'dom':10s} {'T_comp(ms)':>10s} "
           f"{'T_mem(ms)':>10s} {'T_coll(ms)':>10s} {'useful':>7s} "
           f"{'roofline':>8s}")
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for r in load_records(mesh, quant):
        if r["status"] == "skip":
            rows.append(f"{r['arch']:20s} {r['shape']:12s} SKIP ({r['reason'][:60]})")
            continue
        if r["status"] != "ok":
            rows.append(f"{r['arch']:20s} {r['shape']:12s} ERROR {r['error'][:60]}")
            continue
        cfg = get_config(r["arch"])
        rl = analyze(r, cfg)
        rows.append(
            f"{r['arch']:20s} {r['shape']:12s} {rl.dominant:10s} "
            f"{rl.t_compute*1e3:10.3f} {rl.t_memory*1e3:10.3f} "
            f"{rl.t_collective*1e3:10.3f} {rl.useful_ratio:7.3f} "
            f"{rl.roofline_frac:8.3f}")
    return "\n".join(rows)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--quant", type=int, default=None)
    args = ap.parse_args()
    print(report(args.mesh, args.quant))
