"""Production meshes.  Functions, not module-level constants — importing this
module never touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where the installed JAX
    has them (>= 0.5); older releases only have Auto semantics anyway."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(at.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(tp: int = 1):
    """Whatever this host actually has (smoke tests: 1 CPU device), split
    ``(n // tp, tp)`` over ``("data", "model")``.  ``tp > 1`` is how tests
    and the serving CLI build a real host TP mesh (typically under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    n = len(jax.devices())
    if tp < 1 or n % tp:
        raise ValueError(
            f"tp={tp} must be >= 1 and divide the host device count ({n})")
    return _make_mesh((n // tp, tp), ("data", "model"))
