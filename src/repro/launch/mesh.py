"""Production meshes.  Functions, not module-level constants — importing this
module never touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh():
    """Whatever this host actually has (smoke tests: 1 CPU device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), axis_types=_auto(2))
