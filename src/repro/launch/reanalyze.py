"""Re-derive cost fields of every dry-run artifact from its saved .hlo
(after hlocost refinements) without recompiling.

  PYTHONPATH=src python -m repro.launch.reanalyze
"""
import glob
import json
import os

from repro.launch.hlocost import analyze_hlo
from repro.launch.roofline import ART_DIR


def main():
    n = 0
    for jpath in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        hpath = jpath[:-5] + ".hlo"
        if not os.path.exists(hpath):
            continue
        with open(jpath) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        with open(hpath) as f:
            corrected = analyze_hlo(f.read())
        rec["flops"] = corrected["flops"]
        rec["hlo_bytes"] = corrected["bytes"]
        rec["coll_bytes"] = corrected["coll_bytes"]
        rec["coll_by_kind"] = corrected["coll_by_kind"]
        rec["unknown_trip_whiles"] = corrected["unknown_trip_whiles"]
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"re-analyzed {n} artifacts")


if __name__ == "__main__":
    main()
