"""Loop-aware cost analysis of optimized (post-SPMD) HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` on the CPU backend counts
every ``while`` body ONCE, regardless of trip count (verified empirically:
scan of K matmuls reports identical flops for K = 1, 4, 16).  Our production
programs are scan-heavy — layers, gradient-accumulation microbatches, flash-
attention KV chunks — so naive cost_analysis under-reports flops/bytes/
collective traffic by 1-3 orders of magnitude.  This module re-derives the
three roofline inputs by walking the HLO computation graph and multiplying
``while`` bodies by their trip counts (parsed from the scan-induced
``compare(iter, constant(K))`` condition):

  * flops        — 2 * prod(result dims) * prod(contraction dims) per dot
                   (+ convolutions), MXU-relevant work only;
  * hbm bytes    — kernel-IO model: every non-trivial op at computation level
                   (fusions, dots, collectives, copies, reduces) reads its
                   operands and writes its result; fusion internals excluded
                   (that is the point of fusion);
  * collective wire bytes — max(operand, result) per collective instance
                   (ring all-gather sends ~result bytes, reduce-scatter sends
                   ~operand bytes, all-reduce/all-to-all/permute symmetric).

All shapes in the partitioned module are per-device, so totals are per-chip.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_ARR_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
                "s4": 1, "u4": 1}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "partition-id", "replica-id", "iota", "rng-bit-generator"}


def _arrays(text: str):
    for dt, dims in _ARR_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        yield dt, n


def _nbytes(text: str) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, n in _arrays(text))


def _dims(text: str) -> list[int]:
    m = _ARR_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    result: str          # result type text
    opcode: str
    operands: list[str]
    attrs: str
    argtext: str = ""    # raw text inside the op's parens
    is_root: bool = False


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # %name -> result type text


_OP_RE = re.compile(
    r"^(ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{")


def parse_hlo(text: str) -> tuple[dict, str]:
    """-> ({comp_name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            cur = Computation(mc.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if line == "}":
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        is_root, name, result, opcode, rest = mo.groups()
        # operands: %refs inside the first (...) group — cut at the matching
        # close paren by scanning
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        arg_text = rest[: i - 1]
        attrs = rest[i:]
        operands = re.findall(r"%([\w.\-]+)", arg_text)
        op = Op(name=name, result=result, opcode=opcode, operands=operands,
                attrs=attrs, argtext=arg_text, is_root=bool(is_root))
        cur.ops.append(op)
        cur.shapes[name] = result
    return comps, entry


def _called(attrs: str, key: str) -> str | None:
    m = re.search(key + r"=%([\w.\-]+)", attrs)
    return m.group(1) if m else None


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    unknown_trip_whiles: int = 0


class HloCost:
    def __init__(self, text: str):
        self.comps, self.entry = parse_hlo(text)
        self._memo: dict[str, CostTotals] = {}
        # per-computation constant table: %name -> int value (from raw text)
        self._consts: dict[str, int] = {}
        for m in re.finditer(
                r"%([\w.\-]+)\s*=\s*s(?:32|64)\[\]\s*constant\((-?\d+)\)",
                text):
            self._consts[m.group(1)] = int(m.group(2))

    # -------------------------------------------------------------- trips
    def _trips(self, cond_name: str) -> tuple[int, bool]:
        cond = self.comps.get(cond_name)
        if cond is None:
            return 1, False
        vals = [self._consts[op.name] for op in cond.ops
                if op.opcode == "constant" and op.name in self._consts]
        # scan condition: iter < K  => trips = K (iter starts at 0)
        pos = [v for v in vals if v > 0]
        if pos:
            return max(pos), True
        return 1, False

    # --------------------------------------------------------------- dots
    def _dot_flops(self, comp: Computation, op: Op) -> float:
        out = _dims(op.result)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
        lhs_shape = _dims(comp.shapes.get(op.operands[0], ""))
        contract = 1
        if m and lhs_shape:
            for d in m.group(1).split(","):
                if d:
                    contract *= lhs_shape[int(d)]
        n_out = 1
        for d in out:
            n_out *= d
        return 2.0 * n_out * contract

    def _conv_flops(self, comp: Computation, op: Op) -> float:
        out = _dims(op.result)
        ker = _dims(comp.shapes.get(op.operands[1], "")) if len(
            op.operands) > 1 else []
        n_out = 1
        for d in out:
            n_out *= d
        k = 1
        for d in ker:
            k *= d
        # rough: 2 * output elems * kernel elems / output-channels
        if ker:
            k = k // max(ker[-1], 1) if len(ker) >= 2 else k
        return 2.0 * n_out * max(k, 1)

    # ------------------------------------------------------------ walking
    def cost(self, comp_name: str | None = None) -> CostTotals:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps[comp_name]
        t = CostTotals()
        for op in comp.ops:
            base = op.opcode.removesuffix("-start").removesuffix("-done")
            if op.opcode.endswith("-done"):
                continue
            if op.opcode == "while":
                body = _called(op.attrs, "body")
                cond = _called(op.attrs, "condition")
                trips, known = self._trips(cond)
                if not known:
                    t.unknown_trip_whiles += 1
                for sub in (body, cond):
                    if sub and sub in self.comps:
                        c = self.cost(sub)
                        t.flops += trips * c.flops
                        t.bytes += trips * c.bytes
                        t.coll_bytes += trips * c.coll_bytes
                        for k, v in c.coll_by_kind.items():
                            t.coll_by_kind[k] = t.coll_by_kind.get(k, 0) \
                                + trips * v
                        t.unknown_trip_whiles += c.unknown_trip_whiles
                continue
            if op.opcode == "conditional":
                branches = re.findall(r"%([\w.\-]+)", op.attrs)
                subcosts = [self.cost(b) for b in branches
                            if b in self.comps]
                if subcosts:
                    worst = max(subcosts, key=lambda c: c.flops + c.bytes)
                    t.flops += worst.flops
                    t.bytes += worst.bytes
                    t.coll_bytes += worst.coll_bytes
                continue
            if op.opcode in ("call",):
                sub = _called(op.attrs, "to_apply")
                if sub and sub in self.comps:
                    c = self.cost(sub)
                    t.flops += c.flops
                    t.bytes += c.bytes
                    t.coll_bytes += c.coll_bytes
                continue
            if op.opcode == "fusion":
                sub = _called(op.attrs, "calls")
                if sub and sub in self.comps:
                    t.flops += self._flops_only(sub)
                op_bytes = self._io_bytes(comp, op)
                t.bytes += op_bytes
                continue
            if base in _COLLECTIVES:
                in_b = sum(_nbytes(comp.shapes.get(o, ""))
                           for o in op.operands)
                out_b = _nbytes(op.result)
                wire = max(in_b, out_b)
                t.coll_bytes += wire
                t.coll_by_kind[base] = t.coll_by_kind.get(base, 0) + wire
                t.bytes += self._io_bytes(comp, op)
                continue
            if op.opcode == "dot":
                t.flops += self._dot_flops(comp, op)
                t.bytes += self._io_bytes(comp, op)
                continue
            if op.opcode == "convolution":
                t.flops += self._conv_flops(comp, op)
                t.bytes += self._io_bytes(comp, op)
                continue
            if op.opcode in _SKIP_BYTES or op.opcode == "convert":
                continue
            t.bytes += self._io_bytes(comp, op)
        self._memo[comp_name] = t
        return t

    def _flops_only(self, comp_name: str) -> float:
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        f = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                f += self._dot_flops(comp, op)
            elif op.opcode == "convolution":
                f += self._conv_flops(comp, op)
            elif op.opcode == "fusion":
                sub = _called(op.attrs, "calls")
                if sub:
                    f += self._flops_only(sub)
        return f

    def _io_bytes(self, comp: Computation, op: Op) -> float:
        """Physical HBM traffic of one kernel-level op.

        Slicing ops touch only the sliced region (XLA reads/writes the
        window, not the buffer): without this, every scan iteration would be
        charged the full stacked-params array and every decode step the full
        KV cache — the dominant source of error in a naive operand+result
        model.
        """
        oc = op.opcode
        res = _nbytes(op.result)
        if oc in ("dynamic-slice", "slice", "broadcast", "iota", "reverse"):
            return float(res)
        if oc == "dynamic-update-slice":
            upd = _nbytes(comp.shapes.get(op.operands[1], "")) if len(
                op.operands) > 1 else 0
            return float(2 * upd)              # read-modify-write the window
        if oc == "gather":
            idx = _nbytes(comp.shapes.get(op.operands[1], "")) if len(
                op.operands) > 1 else 0
            return float(2 * res + idx)        # rows read + result written
        if oc in ("scatter", "scatter-add"):
            upd = _nbytes(comp.shapes.get(op.operands[-1], ""))
            idx = _nbytes(comp.shapes.get(op.operands[1], "")) if len(
                op.operands) > 2 else 0
            return float(3 * upd + idx)        # read+write window + updates
        if oc == "fusion":
            sub = _called(op.attrs, "calls")
            if self._pure_cast(sub):
                return 0.0      # TPU: dtype casts fuse into consumers
            b = self._fusion_result_bytes(sub, float(res))
            for i, o in enumerate(op.operands):
                full = _nbytes(comp.shapes.get(o, ""))
                b += self._fusion_param_bytes(sub, i, full)
            return b
        b = float(res)
        for o in op.operands:
            b += _nbytes(comp.shapes.get(o, ""))
        return b

    def _fusion_param_bytes(self, comp_name: str | None, param_idx: int,
                            full_bytes: float) -> float:
        """Effective bytes a fused kernel reads from operand ``param_idx``.

        If every use of the parameter inside the fused computation is a
        slicing op (dynamic-slice / slice / gather) or the *target* of a
        dynamic-update-slice, only the windows move through HBM."""
        comp = self.comps.get(comp_name or "")
        if comp is None:
            return full_bytes
        pname = None
        for o in comp.ops:
            if o.opcode == "parameter" and o.argtext.strip() == str(param_idx):
                pname = o.name
                break
        if pname is None:
            return full_bytes
        # Follow the buffer through layout-transparent ops (bitcast/reshape
        # produce no traffic of their own) so e.g. bitcast->dynamic-update-
        # slice chains still count only the window.
        frontier = {pname}
        touched = 0.0
        # TPU semantics: fusion internals never materialize — dtype converts,
        # copies and layout ops inside a fused kernel are free register moves
        transparent = ("bitcast", "reshape", "convert", "copy", "transpose",
                       "broadcast")
        for o in comp.ops:                      # ops are in topological order
            hits = [x for x in o.operands if x in frontier]
            if not hits:
                continue
            if o.opcode in transparent:
                frontier.add(o.name)
            elif o.opcode in ("dynamic-slice", "slice", "gather"):
                touched += _nbytes(o.result)
            elif (o.opcode == "dynamic-update-slice"
                  and o.operands and o.operands[0] in frontier):
                upd = _nbytes(comp.shapes.get(o.operands[1], "")) if len(
                    o.operands) > 1 else 0
                touched += upd
                frontier.add(o.name)            # result aliases the buffer
            else:
                return full_bytes              # some use reads it fully
        return min(touched, full_bytes) if touched else full_bytes

    def _pure_cast(self, comp_name: str | None) -> bool:
        comp = self.comps.get(comp_name or "")
        if comp is None:
            return False
        allowed = {"parameter", "convert", "bitcast", "reshape", "copy",
                   "constant"}
        return all(o.opcode in allowed for o in comp.ops)

    def _fusion_result_bytes(self, comp_name: str | None,
                             full_bytes: float) -> float:
        """A fusion whose root is a dynamic-update-slice aliases its target
        buffer and writes only the update window."""
        comp = self.comps.get(comp_name or "")
        if comp is None:
            return full_bytes
        defs = {o.name: o for o in comp.ops}
        root = next((o for o in comp.ops if o.is_root), None)
        # follow transparent root chains
        seen = 0
        while root is not None and root.opcode in ("bitcast", "reshape",
                                                   "copy", "convert") \
                and seen < 10:
            root = defs.get(root.operands[0]) if root.operands else None
            seen += 1
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = _nbytes(comp.shapes.get(root.operands[1], "")) if len(
                root.operands) > 1 else 0
            return float(upd)
        return full_bytes


def analyze_hlo(text: str) -> dict:
    hc = HloCost(text)
    t = hc.cost()
    return dict(flops=t.flops, bytes=t.bytes, coll_bytes=t.coll_bytes,
                coll_by_kind=dict(t.coll_by_kind),
                unknown_trip_whiles=t.unknown_trip_whiles)
