"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

  PYTHONPATH=src python -m repro.launch.report [--update-experiments]
"""
from __future__ import annotations

import argparse

from repro.configs.registry import get_config
from repro.launch import roofline


def dryrun_table(mesh: str, quant=None) -> str:
    rows = [
        "| arch | shape | status | per-chip FLOPs | per-chip HBM bytes | "
        "coll bytes | peak mem/chip | compile |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in roofline.load_records(mesh, quant):
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP (sub-quadratic "
                        f"n/a) | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — |"
                        f" — | — |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['flops']:.3e} | "
            f"{r['hlo_bytes']:.3e} | {r['coll_bytes']:.3e} | "
            f"{r['memory']['peak']/2**30:.2f} GiB | {r['compile_s']}s |")
    return "\n".join(rows)


def roofline_table(mesh: str, quant=None) -> str:
    rows = [
        "| arch | shape | T_comp (ms) | T_mem (ms) | T_coll (ms) | dominant |"
        " MODEL_FLOPS/chip | useful ratio | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in roofline.load_records(mesh, quant):
        if r["status"] != "ok":
            continue
        cfg = get_config(r["arch"])
        rl = roofline.analyze(r, cfg)
        lever = _lever(r, rl)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rl.t_compute*1e3:.2f} | "
            f"{rl.t_memory*1e3:.2f} | {rl.t_collective*1e3:.2f} | "
            f"{rl.dominant} | {rl.model_flops_per_chip:.3e} | "
            f"{rl.useful_ratio:.3f} | {rl.roofline_frac:.3f} | {lever} |")
    return "\n".join(rows)


def _lever(r: dict, rl) -> str:
    if rl.dominant == "memory":
        if r["kind"] == "decode":
            return "quantize weights/cache (b/16 of bytes)"
        return "cut activation traffic (remat policy, fused loss)"
    if rl.dominant == "collective":
        return "reshard: avoid kv-head padding / overlap a2a"
    return "larger per-chip tiles / batch"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", type=int, default=None)
    args = ap.parse_args()
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### Dry-run {mesh}\n")
        print(dryrun_table(mesh, args.quant))
        print(f"\n### Roofline {mesh}\n")
        print(roofline_table(mesh, args.quant))


if __name__ == "__main__":
    main()
