"""Emit the §Roofline table from dry-run artifacts (no compilation here)."""
from __future__ import annotations

from repro.launch import roofline

from .common import Row


def run(row: Row):
    for mesh in ("16x16", "2x16x16"):
        recs = roofline.load_records(mesh)
        if not recs:
            row.add(f"roofline/{mesh}", 0.0, "no_artifacts")
            continue
        from repro.configs.registry import get_config
        for r in recs:
            if r["status"] != "ok":
                continue
            rl = roofline.analyze(r, get_config(r["arch"]))
            t_dom = max(rl.t_compute, rl.t_memory, rl.t_collective)
            row.add(f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                    t_dom * 1e6,
                    f"dom={rl.dominant};tc_ms={rl.t_compute*1e3:.2f};"
                    f"tm_ms={rl.t_memory*1e3:.2f};"
                    f"tl_ms={rl.t_collective*1e3:.2f};"
                    f"useful={rl.useful_ratio:.3f};"
                    f"frac={rl.roofline_frac:.3f}")
