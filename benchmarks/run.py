"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only table1,table3] [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time

from .common import Row

ALL = ("rabitq_error", "allocate_bench", "table1_quality",
       "table2_calibration", "table3_time", "serve_bench",
       "roofline_report")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark module names")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(ALL)
    row = Row()
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t1 = time.time()
        try:
            mod.run(row)
        except Exception as e:  # keep the harness going; report the failure
            row.add(f"{name}/ERROR", 0.0, f"{type(e).__name__}:{e}")
        print(f"# {name} done in {time.time()-t1:.1f}s", file=sys.stderr,
              flush=True)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
