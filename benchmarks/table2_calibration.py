"""Paper Table 2 / 5 protocol: few-shot (5 samples) vs zero-shot (1 synthetic
sentence) calibration."""
from __future__ import annotations

import time

import jax

from repro.core import pipeline as pipe

from .common import Row, calib_batches, eval_ppl, run_stats, trained_model


def run(row: Row, raana_bits=(2.3, 3.3, 4.3)):
    cfg, params, _, corpus = trained_model()
    for mode in ("few", "zero"):
        batches = calib_batches(cfg, corpus, few_shot=(mode == "few"))
        t0 = time.time()
        stats = run_stats(cfg, params, batches)
        t_cal = time.time() - t0
        for rb in raana_bits:
            qp, rep = pipe.quantize_model(cfg, params, stats, rb,
                                          jax.random.PRNGKey(2))
            ppl = eval_ppl(cfg, qp, corpus)
            row.add(f"table2/raana_{mode}_{rb}b", t_cal * 1e6,
                    f"ppl={ppl:.3f};avg_bits={rep.avg_bits:.2f};"
                    f"n_calib={len(batches)}")
