"""Paper App. A.2 (eq. 11): empirical inner-product error vs the
5.75/(sqrt(d) 2^b) bound — the assumption AllocateBits' alpha model rests on."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hadamard as h
from repro.core import rabitq

from .common import Row


def run(row: Row):
    for d in (512, 2048):
        for bits in (1, 2, 4, 8):
            key = jax.random.PRNGKey(d + bits)
            w = jax.random.normal(key, (d, 64))
            s = h.rademacher(jax.random.fold_in(key, 1), d)
            wr = h.rht(w, s, axis=0)
            t0 = time.time()
            q = rabitq.quantize(wr, bits)
            dt = time.time() - t0
            x = jax.random.normal(jax.random.fold_in(key, 2), (64, d))
            est = rabitq.estimate_matmul(x, q)
            ref = x @ wr
            scale = (jnp.linalg.norm(x, axis=1)[:, None]
                     * jnp.linalg.norm(wr, axis=0)[None, :])
            rel = np.asarray(jnp.abs(est - ref) / scale)
            # normalized: measured p99.9 error as a fraction of the bound
            bound = rabitq.C_ERROR / (np.sqrt(d) * 2 ** bits)
            frac = float(np.quantile(rel, 0.999) / bound)
            row.add(f"rabitq_err/d{d}_b{bits}", dt * 1e6,
                    f"p999_over_bound={frac:.3f};within={(rel < bound).mean():.4f}")
