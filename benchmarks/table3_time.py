"""Paper Table 3 protocol: quantization wall-time scaling with model size —
RaanA vs GPTQ (the heavyweight Hessian-based baseline)."""
from __future__ import annotations

import time

import jax

from repro.baselines.apply import apply_baseline, collect_hessians
from repro.configs import registry
from repro.core import calibrate as cal
from repro.core import pipeline as pipe
from repro.models import transformer as tf

from .common import Row

SIZES = {
    "s": dict(n_layers=2, d_model=128, n_heads=4, n_kv=4, d_ff=384,
              head_dim=32),
    "m": dict(n_layers=4, d_model=256, n_heads=8, n_kv=8, d_ff=768,
              head_dim=32),
    "l": dict(n_layers=6, d_model=512, n_heads=8, n_kv=8, d_ff=1536,
              head_dim=64),
}


def run(row: Row, avg_bits: float = 2.3):
    base = registry.get_tiny("llama2-7b")
    for name, dims in SIZES.items():
        cfg = base.with_(name=f"timebench-{name}", **dims)
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        n_params = sum(x.size for x in jax.tree.leaves(params))
        batch = {"tokens": jax.numpy.asarray(
            cal.zero_shot_tokens(cfg.vocab, 128))}
        # RaanA: calibration (1 bwd pass) + allocate + quantize
        t0 = time.time()
        stats = cal.calibrate(
            lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
            params, [batch])
        qp, rep = pipe.quantize_model(cfg, params, stats, avg_bits,
                                      jax.random.PRNGKey(1))
        t_raana = time.time() - t0
        # GPTQ: hessian collection + per-layer solve
        t0 = time.time()
        hess, norms = collect_hessians(cfg, params, [batch])
        _, _, t_g = apply_baseline(cfg, params, "gptq", 2, hessians=hess)
        t_gptq = time.time() - t0
        row.add(f"table3/quant_time_{name}", t_raana * 1e6,
                f"params={n_params};raana_s={t_raana:.2f};"
                f"gptq_s={t_gptq:.2f};speedup={t_gptq/max(t_raana,1e-9):.2f}x")
