"""Paper Table 1 / 4 protocol: perplexity vs average bits, RaanA (few-shot)
against fp16 and the RTN / GPTQ / AWQ baselines, at container scale."""
from __future__ import annotations

import time

import jax

from repro.baselines.apply import apply_baseline, collect_hessians
from repro.core import pipeline as pipe

from .common import Row, calib_batches, eval_ppl, run_stats, trained_model


def run(row: Row, bits_list=(2, 3, 4), raana_bits=(2.3, 3.3, 4.3)):
    cfg, params, _, corpus = trained_model()
    ppl_fp = eval_ppl(cfg, params, corpus)
    row.add("table1/fp16", 0.0, f"ppl={ppl_fp:.3f};bits=32")

    batches = calib_batches(cfg, corpus, few_shot=True)
    stats = run_stats(cfg, params, batches)
    hess, norms = collect_hessians(cfg, params, batches)

    for b, rb in zip(bits_list, raana_bits):
        for method in ("rtn", "gptq", "awq"):
            t0 = time.time()
            qp, avg_bits, _ = apply_baseline(cfg, params, method, b,
                                             hessians=hess,
                                             x_col_norms=norms)
            dt = time.time() - t0
            ppl = eval_ppl(cfg, qp, corpus)
            row.add(f"table1/{method}_{b}b", dt * 1e6,
                    f"ppl={ppl:.3f};avg_bits={avg_bits:.2f}")
        t0 = time.time()
        qp, rep = pipe.quantize_model(cfg, params, stats, rb,
                                      jax.random.PRNGKey(1))
        dt = time.time() - t0
        ppl = eval_ppl(cfg, qp, corpus)
        row.add(f"table1/raana_{rb}b", dt * 1e6,
                f"ppl={ppl:.3f};avg_bits={rep.avg_bits:.2f}")
