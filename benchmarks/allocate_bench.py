"""Paper §4.1: the divide-by-GCD trick makes the DP tractable — measure the
slot-count reduction and wall time on LLM-shaped instances."""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import allocate

from .common import Row


def run(row: Row):
    rng = np.random.default_rng(0)
    # llama2-7b-shaped: 32 layers x 7 linears with real m_k values
    dims = [(4096, 4096)] * 4 + [(4096, 11008)] * 2 + [(11008, 4096)]
    m = [a * b for a, b in dims] * 32
    alphas = rng.uniform(0.5, 50.0, len(m))
    budget = int(3.0 * sum(m))
    t0 = time.time()
    res = allocate.allocate_bits(alphas, m, budget, list(range(1, 9)))
    dt = time.time() - t0
    g_naive = 1
    naive_slots = budget // g_naive
    row.add("allocate/llama7b_shape", dt * 1e6,
            f"slots={res.n_slots};gcd={res.gcd};"
            f"naive_slots={naive_slots};reduction={naive_slots//max(res.n_slots,1)}x;"
            f"objective={res.objective:.4f}")
    # scaling in L
    for L in (64, 512):
        mm = [4096 * 4096] * L
        aa = rng.uniform(0.5, 50.0, L)
        t0 = time.time()
        r = allocate.allocate_bits(aa, mm, int(3.0 * sum(mm)),
                                   list(range(1, 9)))
        dt = time.time() - t0
        row.add(f"allocate/L{L}", dt * 1e6, f"slots={r.n_slots}")
