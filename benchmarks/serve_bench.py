"""Serving throughput fp vs RaanA-quantized (container-scale proxy for the
paper's §1 memory-bandwidth claim) + weight-bytes-resident accounting, with a
fused-vs-unfused decode A/B: the quantized model is served once through the
fused RHT+qmatmul dispatch and once with the legacy two-kernel composition."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import pipeline as pipe
from repro.kernels.qmatmul import ops as qops
from repro.launch.serve import BatchedServer

from .common import Row, calib_batches, run_stats, trained_model


def _weight_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params)
               if hasattr(x, "dtype"))


def run(row: Row, gen: int = 16, requests: int = 4):
    cfg, params, _, corpus = trained_model()
    prompts = np.tile(np.asarray(corpus[:32], np.int32)[None], (requests, 1))

    def bench(p, label):
        server = BatchedServer(cfg, p, max_context=32 + gen)
        out = server.generate(prompts, 2)           # warmup/compile
        t0 = time.time()
        out = server.generate(prompts, gen)
        dt = time.time() - t0
        row.add(f"serve/{label}", dt / (gen * requests) * 1e6,
                f"tok_s={gen*requests/dt:.1f};weight_bytes={_weight_bytes(p)}")
        return out

    bench(params, "fp32")
    stats = run_stats(cfg, params, calib_batches(cfg, corpus, False))
    qp, rep = pipe.quantize_model(cfg, params, stats, 4.3,
                                  jax.random.PRNGKey(0))
    prev = qops.fused_enabled()
    try:
        qops.set_fused(True)
        bench(qp, "raana_4.3b_fused")
        qops.set_fused(False)
        bench(qp, "raana_4.3b_unfused")
    finally:
        qops.set_fused(prev)
