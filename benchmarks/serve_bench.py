"""Serving benchmarks (container-scale proxy for the paper's §1
memory-bandwidth claim).

Part 1 — uniform batch, fp32 vs RaanA-quantized with a fused-vs-unfused
decode A/B (weight-bytes-resident accounting).

Part 2 — mixed-length Poisson-arrival workload through the continuous-
batching paged engine vs the lockstep baseline, each with the fused and
unfused decode path: throughput (tok/s), per-request latency p50/p95, and
decode-slot occupancy.  Lockstep buckets FIFO requests by prompt length and
holds every slot until the batch's longest request finishes (the hostage
effect the paged engine exists to remove).

Part 3 — shared-system-prompt workload with the prefix cache on vs a cold
pool: greedy outputs must be token-identical, and the prefill-token
reduction equals the cache's measured hit tokens.

Part 4 — self-speculative decoding (DESIGN.md §9): the same weights
dual-quantized (shared calibration + rotation) into a target and a low-bit
draft, served spec-on vs spec-off on a generation-heavy workload; outputs
must stay token-identical (greedy) and the leg records acceptance rate and
the tok/s speedup.

Part 5 — paged-attention kernel vs dense gather (DESIGN.md §10): the
mixed-length Poisson workload served with the decode attention read routed
through the Pallas flash-decode kernel over the block arena vs the gather
reference; outputs must stay token-identical and the leg records tok/s,
p50/p95 and the kernel speedup.  (Off-TPU the kernel leg runs the Pallas
interpreter — the recorded ``interpret_mode`` flags that its speedup is
parity/plumbing verification there, not a perf claim; the perf trajectory
is the TPU story.)

Part 6 — tensor-parallel serving (DESIGN.md §11): the Poisson workload
served TP=2 over a (1, 2) host mesh vs the TP=1 reference; greedy outputs
must be token-identical (recorded as ``token_mismatches``).  Skipped with
a reason when the host has fewer than 2 devices (force them on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=2``).

Part 7 — front-door scheduling (DESIGN.md §12): a bursty two-tenant
workload (a low-priority batch tenant saturating the pool at t=0, a
high-priority chat tenant arriving in bursts) served through the
``Scheduler`` with the SLO controller off vs on.  The chat bursts force
drop-and-replay preemptions of batch requests; the leg records per-tenant
latency p50/p95, decode-gap p50/p95 (the per-token latency the SLO
controller regulates), preemption counts, and greedy parity vs a plain
``engine.run`` of the same requests — preempted-and-replayed outputs must
be token-identical (``token_mismatches``).

Every leg emits the same accounting triple — ``token_mismatches`` (greedy
parity vs its reference leg), ``interpret_mode``, ``device_kind`` — and
everything lands in ``BENCH_serve.json`` so the serving perf trajectory is
tracked across PRs."""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core import pipeline as pipe
from repro.kernels.qmatmul import ops as qops
from repro.launch.serve import BatchedServer
from repro.serve import PagedServer, PoolConfig, Request

from .common import Row, calib_batches, run_stats, trained_model

MAX_SLOTS = 4


def _weight_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params)
               if hasattr(x, "dtype"))


def _poisson_workload(cfg, corpus, n=10, seed=7):
    """Mixed prompt/gen lengths, exponential inter-arrival times."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(0.05))
        plen = int(rng.choice([8, 16, 32]))
        gen = int(rng.integers(4, 17))
        start = int(rng.integers(0, len(corpus) - plen))
        reqs.append(Request(rid=i,
                            prompt=np.asarray(corpus[start:start + plen],
                                              np.int32),
                            max_new=gen, arrival=t))
    return reqs


def _shared_prefix_workload(cfg, corpus, n=8, sys_len=48, tail=8, seed=11):
    """Every request opens with the same system prompt + a distinct tail —
    the workload prefix caching exists for."""
    rng = np.random.default_rng(seed)
    sys_p = np.asarray(corpus[:sys_len], np.int32)
    reqs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(0.03))
        start = int(rng.integers(sys_len, len(corpus) - tail))
        prompt = np.concatenate(
            [sys_p, np.asarray(corpus[start:start + tail], np.int32)])
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new=int(rng.integers(4, 13)), arrival=t))
    return reqs


def _bursty_two_tenant(cfg, corpus, seed=17):
    """Low-priority batch tenant saturates the pool at t=0 with long
    generations; a high-priority chat tenant arrives in two bursts of
    long-prompt short-gen requests.  The bursts land on a full pool, so
    serving chat promptly requires preempting batch work, and chat's
    chunked prefills are what inflate decode gaps for the SLO controller
    to push back on."""
    rng = np.random.default_rng(seed)
    reqs, rid = [], 0
    for _ in range(6):                       # batch: fills 4 slots + queue
        start = int(rng.integers(0, len(corpus) - 16))
        reqs.append(Request(rid=rid, max_new=96, arrival=0.0,
                            prompt=np.asarray(corpus[start:start + 16],
                                              np.int32),
                            tenant="batch", priority=0))
        rid += 1
    for i in range(6):                       # chat: two bursts of three
        start = int(rng.integers(0, len(corpus) - 48))
        reqs.append(Request(rid=rid, max_new=8,
                            arrival=0.1 + (i // 3) * 0.3 + (i % 3) * 0.02,
                            prompt=np.asarray(corpus[start:start + 48],
                                              np.int32),
                            tenant="chat", priority=5))
        rid += 1
    return reqs


def _sched_serve(cfg, params, reqs, slo_p95_ms):
    """Serve ``reqs`` through the front-door Scheduler (SLO controller on
    when ``slo_p95_ms`` is set): per-tenant latencies, decode gaps, and
    scheduler counters.  Compile caches are warmed for every prefill-chunk
    length 1..chunk first — replayed prefills of preempted requests land on
    arbitrary remainder lengths, and a mid-leg XLA compile would swamp the
    decode-gap signal the leg exists to measure."""
    from repro.serve.frontdoor import SchedConfig, Scheduler
    pool = PoolConfig(max_slots=MAX_SLOTS, block_size=8,
                      max_context=max(len(r.prompt) + r.max_new
                                      for r in reqs),
                      prefill_chunk=16, prefix_cache=True)
    engine = PagedServer(cfg, params, pool)
    engine.run([Request(rid=-1 - c, prompt=np.zeros(c, np.int32), max_new=2)
                for c in range(1, pool.prefill_chunk + 1)])
    engine.stats.clear()
    engine.decode_gaps.clear()
    engine.start_clock(reset=True)   # arrivals count from here, not warmup
    sched = Scheduler(engine, SchedConfig(slo_p95_ms=slo_p95_ms))
    for r in reqs:
        sched.submit(r)
    results = {}
    t0 = time.time()
    while sched.has_work() and time.time() - t0 < 300:
        results.update(sched.tick())
    wall = time.time() - t0
    lats, ttfts = {}, {}
    for r in reqs:
        lats.setdefault(r.tenant, []).append(results[r.rid].t_done
                                             - r.arrival)
        ttfts.setdefault(r.tenant, []).append(results[r.rid].ttft_s)
    return {"wall": wall, "lats": lats, "ttfts": ttfts,
            "gaps": np.asarray(engine.decode_gaps, np.float64),
            "sched_stats": dict(sched.stats), "engine_stats": engine.stats,
            "results": results,
            "toks": sum(len(v.tokens) for v in results.values())}


def _spec_workload(cfg, corpus, n=4, plen=12, gen=24, seed=13):
    """Generation-heavy (decode-bound) — where speculation pays."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        start = int(rng.integers(0, len(corpus) - plen))
        reqs.append(Request(rid=i,
                            prompt=np.asarray(corpus[start:start + plen],
                                              np.int32),
                            max_new=gen))
    return reqs


def _paged_serve(cfg, params, reqs, fused: bool, prefix_cache: bool = False,
                 draft_params=None, speculate: int = 0,
                 paged_kernel: bool | None = None, mesh=None):
    pool = PoolConfig(max_slots=MAX_SLOTS, block_size=8,
                      max_context=max(len(r.prompt) + r.max_new
                                      for r in reqs),
                      prefill_chunk=16, prefix_cache=prefix_cache)
    engine = PagedServer(cfg, params, pool, fused=fused,
                         paged_kernel=paged_kernel,
                         draft_params=draft_params, speculate=speculate,
                         mesh=mesh)
    # warm compile caches (decode step + every prefill-chunk length the
    # workload will produce) so the timed region measures serving, not XLA
    chunk_lens = set()
    for r in reqs:
        left = len(r.prompt)
        while left > 0:
            c = min(pool.prefill_chunk, left)
            chunk_lens.add(c)
            left -= c
    engine.run([Request(rid=-1 - i, prompt=np.zeros(c, np.int32), max_new=2)
                for i, c in enumerate(sorted(chunk_lens))])
    engine.stats.clear()
    t0 = time.time()
    results = engine.run(list(reqs))
    wall = time.time() - t0
    lat = [results[r.rid].t_done - r.arrival for r in reqs]
    toks = sum(len(results[r.rid].tokens) for r in reqs)
    return wall, toks, lat, engine.stats, results


def _lockstep_batches(reqs):
    """FIFO batches bucketed by prompt length (lockstep needs one shape)."""
    batches, i = [], 0
    while i < len(reqs):
        plen = len(reqs[i].prompt)
        batch = [reqs[i]]
        i += 1
        while (i < len(reqs) and len(batch) < MAX_SLOTS
               and len(reqs[i].prompt) == plen):
            batch.append(reqs[i])
            i += 1
        batches.append(batch)
    return batches


def _lockstep_serve(cfg, params, reqs, fused: bool):
    """FIFO batches bucketed by prompt length; a batch decodes until its
    longest request finishes, finished requests holding their slot.
    Servers are built and warmed per shape bucket before the clock starts,
    so the comparison measures serving, not per-bucket recompilation.
    Returns outputs per rid too (each sliced to its request's max_new, since
    lockstep over-generates to the batch max) so lockstep legs get the same
    token-parity accounting as paged legs."""
    with qops.fusion(fused):
        batches = _lockstep_batches(list(reqs))
        servers = []
        for batch in batches:
            plen = len(batch[0].prompt)
            gen = max(r.max_new for r in batch)
            server = BatchedServer(cfg, params, max_context=plen + gen)
            server.generate(np.stack([r.prompt for r in batch]), 2)  # warmup
            servers.append((server, gen))
        t0 = time.time()
        lat, toks, outputs = [], 0, {}
        occ_num = occ_den = 0
        for batch, (server, gen) in zip(batches, servers):
            start = max(r.arrival for r in batch)   # lockstep waits for all
            now = time.time() - t0
            if now < start:
                time.sleep(start - now)
            out = server.generate(np.stack([r.prompt for r in batch]), gen)
            done = time.time() - t0
            for bi, r in enumerate(batch):
                lat.append(done - r.arrival)
                toks += r.max_new
                outputs[r.rid] = out[bi, :r.max_new]
            for t in range(gen):                    # slots doing useful work
                occ_num += sum(1 for r in batch if r.max_new > t)
                occ_den += MAX_SLOTS
        return time.time() - t0, toks, lat, occ_num / max(occ_den, 1), outputs


def run(row: Row, gen: int = 16, requests: int = 4):
    cfg, params, _, corpus = trained_model()
    prompts = np.tile(np.asarray(corpus[:32], np.int32)[None], (requests, 1))

    def bench(p, label, fused=True):
        with qops.fusion(fused):
            server = BatchedServer(cfg, p, max_context=32 + gen)
            out = server.generate(prompts, 2)       # warmup/compile
            t0 = time.time()
            out = server.generate(prompts, gen)
            dt = time.time() - t0
        row.add(f"serve/{label}", dt / (gen * requests) * 1e6,
                f"tok_s={gen*requests/dt:.1f};weight_bytes={_weight_bytes(p)}")
        return out

    bench(params, "fp32")
    cal_stats = run_stats(cfg, params, calib_batches(cfg, corpus, False))
    # one calibration pass, two budgets: the 4.3-bit target serves every
    # workload below, the 2.2-bit draft only the speculative leg (its sign
    # leaves alias the served target's — rotation stored once)
    qp, rep, dqp, drep = pipe.quantize_model_dual(
        cfg, params, cal_stats, 4.3, 2.2, jax.random.PRNGKey(0))
    bench(qp, "raana_4.3b_fused", fused=True)
    bench(qp, "raana_4.3b_unfused", fused=False)

    # --- mixed-length Poisson workload: paged vs lockstep x fused/unfused.
    # Every leg (here and below) carries the same accounting triple:
    # token_mismatches (greedy parity vs the poisson_paged_fused reference
    # leg, or the leg's stated A/B partner), interpret_mode (True iff the
    # leg's attention ran the Pallas kernel under the interpreter, i.e.
    # forced on off-TPU), and device_kind.
    device_kind = str(jax.devices()[0].device_kind)
    bench_json: dict = {"workloads": {}}
    reqs = _poisson_workload(cfg, corpus)

    def _mismatches(outputs_by_rid, ref_by_rid, rs=reqs):
        return int(sum(
            not np.array_equal(
                np.asarray(outputs_by_rid[r.rid])[:r.max_new],
                np.asarray(ref_by_rid[r.rid])[:r.max_new])
            for r in rs))

    ref_outputs = None   # poisson_paged_fused outputs, set on the first leg
    for mode in ("paged", "lockstep"):
        for fused in (True, False):
            ttfts = None
            if mode == "paged":
                res = _paged_serve(cfg, qp, reqs, fused)
                if fused:
                    paged_fused = res   # reused as a Part-5 leg below
                wall, toks, lat, estats, results = res
                occ = estats["mean_occupancy"]
                outputs = {rid: r.tokens for rid, r in results.items()}
                ttfts = [results[r.rid].ttft_s for r in reqs]
            else:
                wall, toks, lat, occ, outputs = _lockstep_serve(
                    cfg, qp, reqs, fused)
            if ref_outputs is None:
                ref_outputs = outputs
            mism = _mismatches(outputs, ref_outputs)
            fl = "fused" if fused else "unfused"
            ttft_note = ("" if ttfts is None else
                         f"ttft_p50_s={np.percentile(ttfts, 50):.2f};"
                         f"ttft_p95_s={np.percentile(ttfts, 95):.2f};")
            row.add(f"serve/poisson_{mode}_{fl}", wall / max(toks, 1) * 1e6,
                    f"tok_s={toks/wall:.1f};p50_s={np.percentile(lat, 50):.2f};"
                    f"p95_s={np.percentile(lat, 95):.2f};occupancy={occ:.2f};"
                    f"{ttft_note}token_mismatches={mism}")
            bench_json["workloads"][f"poisson_{mode}_{fl}"] = {
                "tok_s": toks / wall,
                "p50_s": float(np.percentile(lat, 50)),
                "p95_s": float(np.percentile(lat, 95)),
                "occupancy": float(occ),
                "token_mismatches": mism,
                "interpret_mode": False,
                "device_kind": device_kind}
            if ttfts is not None:
                bench_json["workloads"][f"poisson_{mode}_{fl}"].update(
                    ttft_p50_s=float(np.percentile(ttfts, 50)),
                    ttft_p95_s=float(np.percentile(ttfts, 95)))

    # --- shared-system-prompt workload: prefix cache on vs cold pool
    preqs = _shared_prefix_workload(cfg, corpus)
    cold = _paged_serve(cfg, qp, preqs, True, prefix_cache=False)
    warm = _paged_serve(cfg, qp, preqs, True, prefix_cache=True)
    mismatch = sum(
        not np.array_equal(warm[4][r.rid].tokens, cold[4][r.rid].tokens)
        for r in preqs)
    wstats = warm[3]
    saved = wstats.get("prefill_tokens_saved", 0)
    hit_rate = wstats.get("prefix_hit_rate", 0.0)
    for label, (wall, toks, lat, estats, _) in (("cold", cold),
                                                ("warm", warm)):
        row.add(f"serve/shared_prefix_{label}", wall / max(toks, 1) * 1e6,
                f"tok_s={toks/wall:.1f};p50_s={np.percentile(lat, 50):.2f};"
                f"p95_s={np.percentile(lat, 95):.2f};"
                f"prefill_tokens={estats.get('prefill_tokens', 0)};"
                f"hit_rate={estats.get('prefix_hit_rate', 0.0):.2f}")
    tok_s_cold = cold[1] / cold[0]
    tok_s_warm = warm[1] / warm[0]
    row.add("serve/shared_prefix_summary", 0.0,
            f"hit_rate={hit_rate:.2f};prefill_tokens_saved={saved};"
            f"token_mismatches={mismatch};"
            f"speedup={tok_s_warm / max(tok_s_cold, 1e-9):.2f}x")
    # --- self-speculative decoding: dual-quantized draft, spec on vs off
    sreqs = _spec_workload(cfg, corpus)
    base = _paged_serve(cfg, qp, sreqs, True)
    spec = _paged_serve(cfg, qp, sreqs, True, draft_params=dqp, speculate=3)
    spec_mismatch = sum(
        not np.array_equal(spec[4][r.rid].tokens, base[4][r.rid].tokens)
        for r in sreqs)
    sstats = spec[3]
    tok_s_base, tok_s_spec = base[1] / base[0], spec[1] / spec[0]
    row.add("serve/speculative", spec[0] / max(spec[1], 1) * 1e6,
            f"tok_s={tok_s_spec:.1f};baseline_tok_s={tok_s_base:.1f};"
            f"speedup={tok_s_spec / max(tok_s_base, 1e-9):.2f}x;"
            f"acceptance_rate={sstats.get('acceptance_rate', 0.0):.2f};"
            f"draft_bits={drep.avg_bits:.2f};"
            f"token_mismatches={spec_mismatch}")
    bench_json["workloads"]["speculative"] = {
        "tok_s_spec": tok_s_spec,
        "tok_s_baseline": tok_s_base,
        "speedup": tok_s_spec / max(tok_s_base, 1e-9),
        "acceptance_rate": float(sstats.get("acceptance_rate", 0.0)),
        "spec_rounds": int(sstats.get("spec_rounds", 0)),
        "speculate_k": 3,
        "draft_avg_bits": float(drep.avg_bits),
        "token_mismatches": int(spec_mismatch),
        "interpret_mode": False,
        "device_kind": device_kind}

    # --- paged-attention kernel vs dense gather on the Poisson workload.
    # The Part-2 paged-fused leg ran with paged_kernel=None, which resolves
    # to the backend default (kernel on TPU, gather elsewhere) — so it IS
    # one of the two legs here; only the non-default path is served again.
    if jax.default_backend() == "tpu":
        kern = paged_fused
        gather = _paged_serve(cfg, qp, reqs, True, paged_kernel=False)
    else:
        gather = paged_fused
        kern = _paged_serve(cfg, qp, reqs, True, paged_kernel=True)
    kern_mismatch = sum(
        not np.array_equal(kern[4][r.rid].tokens, gather[4][r.rid].tokens)
        for r in reqs)
    tok_s_gather, tok_s_kern = gather[1] / gather[0], kern[1] / kern[0]
    for label, (wall, toks, lat, estats, _) in (("gather", gather),
                                                ("kernel", kern)):
        row.add(f"serve/paged_attn_{label}", wall / max(toks, 1) * 1e6,
                f"tok_s={toks/wall:.1f};p50_s={np.percentile(lat, 50):.2f};"
                f"p95_s={np.percentile(lat, 95):.2f};"
                f"occupancy={estats['mean_occupancy']:.2f}")
    row.add("serve/paged_attn_summary", 0.0,
            f"speedup={tok_s_kern / max(tok_s_gather, 1e-9):.2f}x;"
            f"token_mismatches={kern_mismatch};"
            f"interpret={jax.default_backend() != 'tpu'}")
    bench_json["workloads"]["paged_attention_kernel"] = {
        "tok_s_kernel": tok_s_kern,
        "tok_s_gather": tok_s_gather,
        "speedup": tok_s_kern / max(tok_s_gather, 1e-9),
        "p50_s_kernel": float(np.percentile(kern[2], 50)),
        "p95_s_kernel": float(np.percentile(kern[2], 95)),
        "p50_s_gather": float(np.percentile(gather[2], 50)),
        "p95_s_gather": float(np.percentile(gather[2], 95)),
        "interpret_mode": bool(jax.default_backend() != "tpu"),
        "token_mismatches": int(kern_mismatch),
        "device_kind": device_kind}

    bench_json["workloads"]["shared_prefix"] = {
        "tok_s_warm": warm[1] / warm[0],
        "tok_s_cold": cold[1] / cold[0],
        "p50_s_warm": float(np.percentile(warm[2], 50)),
        "p95_s_warm": float(np.percentile(warm[2], 95)),
        "occupancy": float(wstats["mean_occupancy"]),
        "prefix_hit_rate": float(hit_rate),
        "prefill_tokens_saved": int(saved),
        "prefill_tokens_cold": int(cold[3].get("prefill_tokens", 0)),
        "prefill_tokens_warm": int(wstats.get("prefill_tokens", 0)),
        "token_mismatches": int(mismatch),
        "interpret_mode": False,
        "device_kind": device_kind}

    # --- tensor-parallel: TP=2 over a (1, 2) host mesh vs the TP=1
    # reference leg (DESIGN.md §11).  Greedy outputs must be token-
    # identical — the TP boundary gathers disjoint column slices, it never
    # sums partial products.  Needs 2 devices; on CPU run the bench under
    # XLA_FLAGS=--xla_force_host_platform_device_count=2.
    n_dev = len(jax.devices())
    if n_dev >= 2 and n_dev % 2 == 0:
        from repro.launch.mesh import make_host_mesh
        tp2 = _paged_serve(cfg, qp, reqs, True, mesh=make_host_mesh(tp=2))
        tp_mismatch = sum(
            not np.array_equal(tp2[4][r.rid].tokens,
                               paged_fused[4][r.rid].tokens)
            for r in reqs)
        tok_s_tp1 = paged_fused[1] / paged_fused[0]
        tok_s_tp2 = tp2[1] / tp2[0]
        row.add("serve/tp2_vs_tp1", tp2[0] / max(tp2[1], 1) * 1e6,
                f"tok_s_tp2={tok_s_tp2:.1f};tok_s_tp1={tok_s_tp1:.1f};"
                f"speedup={tok_s_tp2 / max(tok_s_tp1, 1e-9):.2f}x;"
                f"token_mismatches={tp_mismatch}")
        bench_json["workloads"]["tp2_vs_tp1"] = {
            "tp": 2,
            "tok_s_tp2": tok_s_tp2,
            "tok_s_tp1": tok_s_tp1,
            "speedup": tok_s_tp2 / max(tok_s_tp1, 1e-9),
            "p50_s_tp2": float(np.percentile(tp2[2], 50)),
            "p95_s_tp2": float(np.percentile(tp2[2], 95)),
            "token_mismatches": int(tp_mismatch),
            "interpret_mode": False,
            "device_kind": device_kind}
    else:
        bench_json["workloads"]["tp2_vs_tp1"] = {
            "skipped": (f"needs an even device count >= 2 (have {n_dev}); "
                        "on CPU run under XLA_FLAGS="
                        "--xla_force_host_platform_device_count=2"),
            "device_kind": device_kind}
    # --- front-door scheduling: bursty two-tenant workload, SLO off vs on
    # (DESIGN.md §12).  The off leg measures how badly chat's chunked
    # prefills inflate decode gaps when admission is ungoverned; its gap
    # distribution then sets the on leg's target (between the p50 decode
    # floor and the inflated p95, so the controller has both something to
    # fix and room to fix it).  Both legs must stay token-identical to a
    # plain engine.run of the same requests — preemption replay included.
    breqs = _bursty_two_tenant(cfg, corpus)
    ref = _paged_serve(cfg, qp, _bursty_two_tenant(cfg, corpus), True,
                       prefix_cache=True)[4]
    off = _sched_serve(cfg, qp, _bursty_two_tenant(cfg, corpus), None)
    gap_ms_off = off["gaps"] * 1e3
    slo_ms = float(min(1.5 * np.percentile(gap_ms_off, 50),
                       0.7 * np.percentile(gap_ms_off, 95)))
    on = _sched_serve(cfg, qp, _bursty_two_tenant(cfg, corpus), slo_ms)
    gap_ms_on = on["gaps"] * 1e3
    sched_mismatch = sum(
        not np.array_equal(leg["results"][r.rid].tokens, ref[r.rid].tokens)
        for leg in (off, on) for r in breqs)
    p95_off, p95_on = (float(np.percentile(gap_ms_off, 95)),
                       float(np.percentile(gap_ms_on, 95)))
    row.add("serve/frontdoor_slo", on["wall"] / max(on["toks"], 1) * 1e6,
            f"slo_p95_ms={slo_ms:.1f};gap_p95_ms_off={p95_off:.1f};"
            f"gap_p95_ms_on={p95_on:.1f};"
            f"preemptions_off={off['sched_stats'].get('preempted', 0)};"
            f"preemptions_on={on['sched_stats'].get('preempted', 0)};"
            f"chat_p95_s_on={np.percentile(on['lats']['chat'], 95):.2f};"
            f"token_mismatches={sched_mismatch}")
    per_tenant = {}
    for tenant in ("batch", "chat"):
        per_tenant[tenant] = {
            "p50_s_off": float(np.percentile(off["lats"][tenant], 50)),
            "p95_s_off": float(np.percentile(off["lats"][tenant], 95)),
            "p50_s_on": float(np.percentile(on["lats"][tenant], 50)),
            "p95_s_on": float(np.percentile(on["lats"][tenant], 95)),
            "ttft_p50_s_on": float(np.percentile(on["ttfts"][tenant], 50)),
            "ttft_p95_s_on": float(np.percentile(on["ttfts"][tenant], 95))}
    bench_json["workloads"]["frontdoor_slo"] = {
        "slo_p95_ms": slo_ms,
        "decode_gap_p50_ms_off": float(np.percentile(gap_ms_off, 50)),
        "decode_gap_p95_ms_off": p95_off,
        "decode_gap_p50_ms_on": float(np.percentile(gap_ms_on, 50)),
        "decode_gap_p95_ms_on": p95_on,
        "slo_gap_p95_improved": bool(p95_on < p95_off),
        "preemptions_off": int(off["sched_stats"].get("preempted", 0)),
        "preemptions_on": int(on["sched_stats"].get("preempted", 0)),
        "replays_on": int(on["engine_stats"].get("replays", 0)),
        "slo_throttled_ticks": int(
            on["sched_stats"].get("slo_throttled_ticks", 0)),
        "per_tenant": per_tenant,
        "token_mismatches": int(sched_mismatch),
        "interpret_mode": False,
        "device_kind": device_kind}

    with open("BENCH_serve.json", "w") as f:
        json.dump(bench_json, f, indent=2, sort_keys=True)
        f.write("\n")
