"""Shared benchmark substrate: one trained tiny model reused by every
quality table (the paper's protocol at container scale), plus perplexity
evaluation."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import calibrate as cal
from repro.data import LMBatchLoader, make_corpus_tokens
from repro.launch.train import train
from repro.models import transformer as tf

EVAL_SEQ = 128
EVAL_BATCHES = 4


@functools.lru_cache(maxsize=2)
def trained_model(arch: str = "llama2-7b", steps: int = 300):
    cfg, params, losses = train(arch=arch, tiny=True, steps=steps, batch=16,
                                seq=EVAL_SEQ, lr=2e-3, log_every=10 ** 9)
    corpus = make_corpus_tokens(cfg.vocab, 30000, seed=0)
    return cfg, params, losses, corpus


def eval_ppl(cfg, params, corpus, scan=False) -> float:
    loader = LMBatchLoader(corpus, 8, EVAL_SEQ)
    nll = []
    for b in loader.eval_batches(EVAL_BATCHES):
        nll.append(float(tf.loss_fn(cfg, params, {"tokens": jnp.asarray(b)},
                                    scan=scan)))
    return float(np.exp(np.mean(nll)))


def calib_batches(cfg, corpus, few_shot: bool, n: int = 5):
    if few_shot:
        loader = LMBatchLoader(corpus, 1, EVAL_SEQ, seed=123)
        return [{"tokens": jnp.asarray(loader.next_batch())}
                for _ in range(n)]
    toks = cal.zero_shot_tokens(cfg.vocab, EVAL_SEQ)
    return [{"tokens": jnp.asarray(toks)}]


def run_stats(cfg, params, batches):
    return cal.calibrate(
        lambda p, b, ctx: tf.loss_fn(cfg, p, b, ctx=ctx, scan=False),
        params, batches)


class Row:
    def __init__(self):
        self.rows = []

    def add(self, name: str, us_per_call: float, derived: str):
        self.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.1f},{derived}", flush=True)
